//! Ball query: nearest-K-within-radius grouping (PointNet++ convention).
//!
//! Mirrors python/compile/sampling.py `ball_query`: for each center, take the
//! K nearest points within `radius`; unfilled slots repeat the nearest valid
//! member; an empty ball falls back to the globally nearest point.
//!
//! §Perf: a uniform grid (cell size = radius) prunes the candidate set from
//! N to the 27 neighboring cells, turning the O(M*N) scan into ~O(M*K) for
//! indoor point densities (see EXPERIMENTS.md §Perf for the before/after).
//! The production [`GridStorage`] packs every cell's members into flat
//! SoA coordinate arrays, so the per-candidate distance loop runs as
//! fixed-width `[f32; LANES]` chunks over contiguous memory; grid storage
//! and the candidate list live in the per-worker `ScratchArena`, so the
//! steady-state query allocates nothing. `ball_query_par` additionally
//! spreads the per-center loop over scoped threads — every center's result
//! is independent, so the output is identical for any thread count.
//!
//! [`ScalarGrid`] and `ball_query_scalar` keep the original one-`Vec`-per-
//! cell scalar implementation verbatim as the reference oracle (candidates
//! are ranked by the total order `(d2, index)`, so packed SIMD collection
//! order cannot change results — pinned by `scalar_oracle_matches_simd`).

use std::collections::HashMap;

use super::arena::{with_arena, ScratchArena};
use super::soa::{PointsSoA, LANES};
use crate::exec::par_map;

/// Uniform hash grid over a point cloud — the original scalar layout
/// (one index `Vec` per cell), kept as the reference oracle and shared
/// with `interp`'s scalar 3-NN path.
pub(crate) struct ScalarGrid {
    cell: f32,
    cells: HashMap<(i32, i32, i32), Vec<u32>>,
}

impl ScalarGrid {
    pub(crate) fn build(xyz: &[[f32; 3]], cell: f32) -> ScalarGrid {
        let mut cells: HashMap<(i32, i32, i32), Vec<u32>> =
            HashMap::with_capacity(xyz.len() / 2);
        for (i, p) in xyz.iter().enumerate() {
            cells
                .entry(Self::key(p, cell))
                .or_default()
                .push(i as u32);
        }
        ScalarGrid { cell, cells }
    }

    pub(crate) fn cell_size(&self) -> f32 {
        self.cell
    }

    #[inline]
    pub(crate) fn key(p: &[f32; 3], cell: f32) -> (i32, i32, i32) {
        (
            (p[0] / cell).floor() as i32,
            (p[1] / cell).floor() as i32,
            (p[2] / cell).floor() as i32,
        )
    }

    /// Visit all points in the 27 cells around `c`.
    #[inline]
    pub(crate) fn neighbors(&self, c: &[f32; 3], mut f: impl FnMut(u32)) {
        let (kx, ky, kz) = Self::key(c, self.cell);
        for dx in -1..=1 {
            for dy in -1..=1 {
                for dz in -1..=1 {
                    if let Some(v) = self.cells.get(&(kx + dx, ky + dy, kz + dz)) {
                        for &i in v {
                            f(i);
                        }
                    }
                }
            }
        }
    }

    /// Visit all points in cells at Chebyshev distance exactly `ring` from
    /// the cell containing `c` (ring 0 = the center cell itself). Used by
    /// the expanding 3-NN search in `interp`. Enumerates only the shell's
    /// six faces — O(ring²) cells, not O(ring³).
    pub(crate) fn ring(&self, c: &[f32; 3], ring: i32, mut f: impl FnMut(u32)) {
        let (kx, ky, kz) = Self::key(c, self.cell);
        let mut cell = |dx: i32, dy: i32, dz: i32| {
            if let Some(v) = self.cells.get(&(kx + dx, ky + dy, kz + dz)) {
                for &i in v {
                    f(i);
                }
            }
        };
        if ring == 0 {
            cell(0, 0, 0);
            return;
        }
        // z = ±ring full faces; y = ±ring minus the z edges; x = ±ring core
        for dx in -ring..=ring {
            for dy in -ring..=ring {
                cell(dx, dy, -ring);
                cell(dx, dy, ring);
            }
        }
        for dx in -ring..=ring {
            for dz in -(ring - 1)..=(ring - 1) {
                cell(dx, -ring, dz);
                cell(dx, ring, dz);
            }
        }
        for dy in -(ring - 1)..=(ring - 1) {
            for dz in -(ring - 1)..=(ring - 1) {
                cell(-ring, dy, dz);
                cell(ring, dy, dz);
            }
        }
    }
}

/// Packed uniform grid: every cell is a `(start, end)` range into flat SoA
/// coordinate + id arrays, so candidate scans stream contiguous memory in
/// SIMD lanes instead of chasing one heap `Vec` per cell. Rebuilt in place
/// inside the scratch arena — zero steady-state allocations.
#[derive(Debug, Default)]
pub struct GridStorage {
    cell: f32,
    cells: HashMap<(i32, i32, i32), (u32, u32)>,
    xs: Vec<f32>,
    ys: Vec<f32>,
    zs: Vec<f32>,
    ids: Vec<u32>,
}

impl GridStorage {
    #[inline]
    fn key(p: [f32; 3], cell: f32) -> (i32, i32, i32) {
        (
            (p[0] / cell).floor() as i32,
            (p[1] / cell).floor() as i32,
            (p[2] / cell).floor() as i32,
        )
    }

    /// Rebuild over `pts` with the given cell size, reusing all storage.
    /// Count pass -> running-offset pass -> scatter: each cell's value is
    /// `(start, cursor)` during the scatter and `(start, end)` after it.
    pub(crate) fn build(&mut self, pts: &PointsSoA, cell: f32) {
        self.cell = cell;
        self.cells.clear();
        let n = pts.len();
        self.xs.clear();
        self.xs.resize(n, 0.0);
        self.ys.clear();
        self.ys.resize(n, 0.0);
        self.zs.clear();
        self.zs.resize(n, 0.0);
        self.ids.clear();
        self.ids.resize(n, 0);
        for i in 0..n {
            self.cells.entry(Self::key(pts.get(i), cell)).or_insert((0, 0)).0 += 1;
        }
        let mut off = 0u32;
        for v in self.cells.values_mut() {
            let count = v.0;
            v.0 = off;
            v.1 = off;
            off += count;
        }
        for i in 0..n {
            let v = self
                .cells
                .get_mut(&Self::key(pts.get(i), cell))
                .expect("cell exists after count pass");
            let slot = v.1 as usize;
            let p = pts.get(i);
            self.xs[slot] = p[0];
            self.ys[slot] = p[1];
            self.zs[slot] = p[2];
            self.ids[slot] = i as u32;
            v.1 += 1;
        }
    }

    pub(crate) fn cell_size(&self) -> f32 {
        self.cell
    }

    #[inline]
    fn cell_slices(
        &self,
        key: (i32, i32, i32),
        f: &mut impl FnMut(&[f32], &[f32], &[f32], &[u32]),
    ) {
        if let Some(&(s, e)) = self.cells.get(&key) {
            let (s, e) = (s as usize, e as usize);
            f(&self.xs[s..e], &self.ys[s..e], &self.zs[s..e], &self.ids[s..e]);
        }
    }

    /// Visit the packed member slices of the 27 cells around `c`.
    #[inline]
    pub(crate) fn neighbors(
        &self,
        c: [f32; 3],
        mut f: impl FnMut(&[f32], &[f32], &[f32], &[u32]),
    ) {
        let (kx, ky, kz) = Self::key(c, self.cell);
        for dx in -1..=1 {
            for dy in -1..=1 {
                for dz in -1..=1 {
                    self.cell_slices((kx + dx, ky + dy, kz + dz), &mut f);
                }
            }
        }
    }

    /// Visit the packed member slices of cells at Chebyshev distance exactly
    /// `ring` (same shell enumeration as [`ScalarGrid::ring`]).
    pub(crate) fn ring(
        &self,
        c: [f32; 3],
        ring: i32,
        mut f: impl FnMut(&[f32], &[f32], &[f32], &[u32]),
    ) {
        let (kx, ky, kz) = Self::key(c, self.cell);
        if ring == 0 {
            self.cell_slices((kx, ky, kz), &mut f);
            return;
        }
        for dx in -ring..=ring {
            for dy in -ring..=ring {
                self.cell_slices((kx + dx, ky + dy, kz - ring), &mut f);
                self.cell_slices((kx + dx, ky + dy, kz + ring), &mut f);
            }
        }
        for dx in -ring..=ring {
            for dz in -(ring - 1)..=(ring - 1) {
                self.cell_slices((kx + dx, ky - ring, kz + dz), &mut f);
                self.cell_slices((kx + dx, ky + ring, kz + dz), &mut f);
            }
        }
        for dy in -(ring - 1)..=(ring - 1) {
            for dz in -(ring - 1)..=(ring - 1) {
                self.cell_slices((kx - ring, ky + dy, kz + dz), &mut f);
                self.cell_slices((kx + ring, ky + dy, kz + dz), &mut f);
            }
        }
    }

    /// Pre-size for an `n`-point cloud (arena warm-up).
    pub(crate) fn reserve(&mut self, n: usize) {
        self.xs.reserve(n.saturating_sub(self.xs.len()));
        self.ys.reserve(n.saturating_sub(self.ys.len()));
        self.zs.reserve(n.saturating_sub(self.zs.len()));
        self.ids.reserve(n.saturating_sub(self.ids.len()));
        self.cells.reserve((n / 2).saturating_sub(self.cells.len()));
    }

    /// Heap bytes currently reserved (arena growth accounting).
    pub(crate) fn capacity_bytes(&self) -> u64 {
        ((self.xs.capacity() + self.ys.capacity() + self.zs.capacity() + self.ids.capacity())
            * 4) as u64
            + (self.cells.capacity()
                * std::mem::size_of::<((i32, i32, i32), (u32, u32))>()) as u64
    }
}

/// Collect in-radius candidates from one packed cell slice: distance lanes
/// first (same per-element op order as the scalar oracle), then the radius
/// test. `ids` carries the original point indices.
#[inline]
fn collect_hits(
    c: [f32; 3],
    r2: f32,
    xs: &[f32],
    ys: &[f32],
    zs: &[f32],
    ids: &[u32],
    hits: &mut Vec<(f32, usize)>,
) {
    let len = ids.len();
    let mut i = 0;
    while i + LANES <= len {
        let mut d2 = [0.0f32; LANES];
        for l in 0..LANES {
            let dx = xs[i + l] - c[0];
            let dy = ys[i + l] - c[1];
            let dz = zs[i + l] - c[2];
            d2[l] = dx * dx + dy * dy + dz * dz;
        }
        for l in 0..LANES {
            if d2[l] <= r2 {
                hits.push((d2[l], ids[i + l] as usize));
            }
        }
        i += LANES;
    }
    for j in i..len {
        let dx = xs[j] - c[0];
        let dy = ys[j] - c[1];
        let dz = zs[j] - c[2];
        let d2 = dx * dx + dy * dy + dz * dz;
        if d2 <= r2 {
            hits.push((d2, ids[j] as usize));
        }
    }
}

/// Globally nearest point to `c` (empty-ball fallback) — scalar scan in
/// index order, bitwise the same rule as the brute-force reference.
fn nearest_point(pts: &PointsSoA, c: [f32; 3], ci: usize) -> usize {
    let (xs, ys, zs) = (pts.xs(), pts.ys(), pts.zs());
    let mut nearest = (f32::INFINITY, ci);
    for j in 0..pts.len() {
        let dx = xs[j] - c[0];
        let dy = ys[j] - c[1];
        let dz = zs[j] - c[2];
        let d2 = dx * dx + dy * dy + dz * dz;
        if d2 < nearest.0 {
            nearest = (d2, j);
        }
    }
    nearest.1
}

/// One center's group: K nearest in-radius members (grid-pruned candidates).
fn query_one(
    grid: &GridStorage,
    pts: &PointsSoA,
    ci: usize,
    r2: f32,
    k: usize,
    hits: &mut Vec<(f32, usize)>,
) -> Vec<usize> {
    let c = pts.get(ci);
    hits.clear();
    grid.neighbors(c, |xs, ys, zs, ids| collect_hits(c, r2, xs, ys, zs, ids, hits));
    // (d2, index) is a total order over distinct indices, so the sorted
    // prefix is unique no matter what order the packed cells emitted hits
    hits.sort_unstable_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    let mut out: Vec<usize> = hits.iter().take(k).map(|&(_, j)| j).collect();
    let fill = out.first().copied().unwrap_or_else(|| nearest_point(pts, c, ci));
    out.resize(k, fill);
    out
}

/// Shared SIMD implementation over prepared scratch buffers.
fn ball_query_core(
    pts: &PointsSoA,
    centers: &[usize],
    radius: f32,
    k: usize,
    threads: usize,
    grid: &mut GridStorage,
    hits: &mut Vec<(f32, usize)>,
) -> Vec<Vec<usize>> {
    let r2 = radius * radius;
    grid.build(pts, radius);
    // clamp the raw thread budget: more threads than centers is never useful
    let threads = threads.clamp(1, centers.len().max(1));
    if threads <= 1 || centers.len() < 64 {
        return centers.iter().map(|&ci| query_one(grid, pts, ci, r2, k, hits)).collect();
    }
    let grid = &*grid;
    par_map(centers, threads, |_, &ci| {
        // worker threads own their own arenas — only the candidate list is
        // needed per center, the grid is shared read-only
        with_arena(|a| query_one(grid, pts, ci, r2, k, &mut a.hits))
    })
}

/// Returns (M, K) neighbor indices for each center index.
pub fn ball_query(
    xyz: &[[f32; 3]],
    centers: &[usize],
    radius: f32,
    k: usize,
) -> Vec<Vec<usize>> {
    ball_query_par(xyz, centers, radius, k, 1)
}

/// `ball_query` with the per-center loop spread over up to `threads`
/// scoped threads (clamped to the center count; 0 behaves as 1).
/// Identical output for any thread count.
pub fn ball_query_par(
    xyz: &[[f32; 3]],
    centers: &[usize],
    radius: f32,
    k: usize,
    threads: usize,
) -> Vec<Vec<usize>> {
    with_arena(|a| {
        let ScratchArena { soa, grid, hits, .. } = a;
        soa.fill_from_points(xyz);
        ball_query_core(soa, centers, radius, k, threads, grid, hits)
    })
}

/// `ball_query` over a cloud already in SoA layout (the pipeline's steady
/// path — skips the conversion copy).
pub fn ball_query_soa(
    pts: &PointsSoA,
    centers: &[usize],
    radius: f32,
    k: usize,
    threads: usize,
) -> Vec<Vec<usize>> {
    with_arena(|a| {
        let ScratchArena { grid, hits, .. } = a;
        ball_query_core(pts, centers, radius, k, threads, grid, hits)
    })
}

/// One center's group on the scalar reference grid (the pre-SIMD code).
fn scalar_query_one(
    grid: &ScalarGrid,
    xyz: &[[f32; 3]],
    ci: usize,
    r2: f32,
    k: usize,
    hits: &mut Vec<(f32, usize)>,
) -> Vec<usize> {
    let c = xyz[ci];
    hits.clear();
    grid.neighbors(&c, |j| {
        let p = xyz[j as usize];
        let dx = p[0] - c[0];
        let dy = p[1] - c[1];
        let dz = p[2] - c[2];
        let d2 = dx * dx + dy * dy + dz * dz;
        if d2 <= r2 {
            hits.push((d2, j as usize));
        }
    });
    hits.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    let mut out: Vec<usize> = hits.iter().take(k).map(|&(_, j)| j).collect();
    let fill = out.first().copied().unwrap_or_else(|| {
        // empty ball (rare): brute-force global nearest
        let mut nearest = (f32::INFINITY, ci);
        for (j, p) in xyz.iter().enumerate() {
            let dx = p[0] - c[0];
            let dy = p[1] - c[1];
            let dz = p[2] - c[2];
            let d2 = dx * dx + dy * dy + dz * dz;
            if d2 < nearest.0 {
                nearest = (d2, j);
            }
        }
        nearest.1
    });
    out.resize(k, fill);
    out
}

/// Scalar reference implementation (original grid path) — the oracle the
/// SIMD path is pinned bit-identical to, and the baseline `BENCH_hotpath`
/// measures speedups against.
pub fn ball_query_scalar(
    xyz: &[[f32; 3]],
    centers: &[usize],
    radius: f32,
    k: usize,
) -> Vec<Vec<usize>> {
    let r2 = radius * radius;
    let grid = ScalarGrid::build(xyz, radius);
    let mut hits: Vec<(f32, usize)> = Vec::with_capacity(64);
    centers.iter().map(|&ci| scalar_query_one(&grid, xyz, ci, r2, k, &mut hits)).collect()
}

/// Reference O(M*N) implementation kept for tests and the §Perf comparison.
pub fn ball_query_bruteforce(
    xyz: &[[f32; 3]],
    centers: &[usize],
    radius: f32,
    k: usize,
) -> Vec<Vec<usize>> {
    let r2 = radius * radius;
    centers
        .iter()
        .map(|&ci| {
            let c = xyz[ci];
            let mut hits: Vec<(f32, usize)> = Vec::with_capacity(k * 2);
            let mut nearest = (f32::INFINITY, ci);
            for (j, p) in xyz.iter().enumerate() {
                let dx = p[0] - c[0];
                let dy = p[1] - c[1];
                let dz = p[2] - c[2];
                let d2 = dx * dx + dy * dy + dz * dz;
                if d2 < nearest.0 {
                    nearest = (d2, j);
                }
                if d2 <= r2 {
                    hits.push((d2, j));
                }
            }
            hits.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
            hits.truncate(k);
            let mut out: Vec<usize> = hits.iter().map(|&(_, j)| j).collect();
            let fill = out.first().copied().unwrap_or(nearest.1);
            out.resize(k, fill);
            out
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cloud(n: usize, seed: u64) -> Vec<[f32; 3]> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| [r.f32() * 2.0, r.f32() * 2.0, r.f32()]).collect()
    }

    fn d2(a: [f32; 3], b: [f32; 3]) -> f32 {
        (a[0] - b[0]).powi(2) + (a[1] - b[1]).powi(2) + (a[2] - b[2]).powi(2)
    }

    #[test]
    fn grid_matches_bruteforce() {
        for seed in 0..6 {
            let pts = cloud(500, seed);
            let centers: Vec<usize> = (0..32).map(|i| i * 15).collect();
            for (r, k) in [(0.15, 8), (0.4, 16), (0.9, 4)] {
                let a = ball_query(&pts, &centers, r, k);
                let b = ball_query_bruteforce(&pts, &centers, r, k);
                assert_eq!(a, b, "seed {seed} r {r} k {k}");
            }
        }
    }

    #[test]
    fn scalar_oracle_matches_simd() {
        for seed in 0..6 {
            let pts = cloud(700, seed + 50);
            let centers: Vec<usize> = (0..64).map(|i| i * 10).collect();
            for (r, k) in [(0.15, 8), (0.4, 16)] {
                assert_eq!(
                    ball_query(&pts, &centers, r, k),
                    ball_query_scalar(&pts, &centers, r, k),
                    "seed {seed} r {r} k {k}"
                );
            }
        }
    }

    #[test]
    fn soa_entry_point_matches_interleaved() {
        let pts = cloud(600, 77);
        let soa = crate::pointops::soa::PointsSoA::from_points(&pts);
        let centers: Vec<usize> = (0..80).map(|i| i * 7).collect();
        for threads in [1, 4] {
            assert_eq!(
                ball_query_soa(&soa, &centers, 0.3, 8, threads),
                ball_query(&pts, &centers, 0.3, 8),
                "threads {threads}"
            );
        }
    }

    #[test]
    fn parallel_matches_sequential() {
        let pts = cloud(2000, 11);
        let centers: Vec<usize> = (0..200).map(|i| i * 10).collect();
        let seq = ball_query(&pts, &centers, 0.35, 12);
        for threads in [2, 3, 8] {
            assert_eq!(ball_query_par(&pts, &centers, 0.35, 12, threads), seq);
        }
    }

    #[test]
    fn thread_budget_is_clamped() {
        // threads == 0 and threads far beyond the center count must both
        // behave exactly like the sequential path
        let pts = cloud(800, 13);
        let centers: Vec<usize> = (0..100).map(|i| i * 8).collect();
        let seq = ball_query(&pts, &centers, 0.3, 8);
        assert_eq!(ball_query_par(&pts, &centers, 0.3, 8, 0), seq, "threads=0");
        assert_eq!(ball_query_par(&pts, &centers, 0.3, 8, 10_000), seq, "threads>n");
    }

    #[test]
    fn all_members_within_radius_or_fill() {
        let pts = cloud(400, 1);
        let centers = vec![0, 5, 100];
        let r = 0.4;
        let groups = ball_query(&pts, &centers, r, 16);
        for (g, &ci) in groups.iter().zip(centers.iter()) {
            assert_eq!(g.len(), 16);
            let first = g[0];
            for &j in g {
                assert!(d2(pts[j], pts[ci]) <= r * r + 1e-6 || j == first);
            }
        }
    }

    #[test]
    fn center_is_own_nearest_member() {
        let pts = cloud(200, 2);
        let groups = ball_query(&pts, &[7], 1.0, 8);
        assert_eq!(groups[0][0], 7, "nearest in-radius point is the center itself");
    }

    #[test]
    fn empty_ball_falls_back_to_nearest() {
        let mut pts = cloud(50, 3);
        pts.push([100.0, 100.0, 100.0]); // isolated center
        let groups = ball_query(&pts, &[50], 0.1, 4);
        assert!(groups[0].iter().all(|&j| j == 50));
    }

    #[test]
    fn members_sorted_by_distance() {
        let pts = cloud(300, 4);
        let groups = ball_query(&pts, &[3], 0.8, 12);
        let g = &groups[0];
        for w in g.windows(2) {
            let (a, b) = (d2(pts[w[0]], pts[3]), d2(pts[w[1]], pts[3]));
            assert!(a <= b + 1e-6 || w[1] == g[0]);
        }
    }

    #[test]
    fn negative_coordinates_handled() {
        let mut r = Rng::new(9);
        let pts: Vec<[f32; 3]> = (0..300)
            .map(|_| [r.f32() * 4.0 - 2.0, r.f32() * 4.0 - 2.0, r.f32() - 0.5])
            .collect();
        let centers = vec![0, 10, 200];
        assert_eq!(
            ball_query(&pts, &centers, 0.5, 8),
            ball_query_bruteforce(&pts, &centers, 0.5, 8)
        );
    }

    #[test]
    fn ring_zero_is_center_cell_and_rings_partition() {
        // visiting rings 0..=R must hit every point exactly once once R
        // spans the cloud — on the scalar grid and the packed grid alike
        let pts = cloud(300, 12);
        let grid = ScalarGrid::build(&pts, 0.5);
        let c = [1.0f32, 1.0, 0.5];
        let mut seen = vec![0usize; pts.len()];
        for ring in 0..8 {
            grid.ring(&c, ring, |j| seen[j as usize] += 1);
        }
        assert!(seen.iter().all(|&s| s == 1), "rings must partition the grid");

        let soa = crate::pointops::soa::PointsSoA::from_points(&pts);
        let mut packed = GridStorage::default();
        packed.build(&soa, 0.5);
        let mut seen = vec![0usize; pts.len()];
        for ring in 0..8 {
            packed.ring(c, ring, |_, _, _, ids| {
                for &j in ids {
                    seen[j as usize] += 1;
                }
            });
        }
        assert!(seen.iter().all(|&s| s == 1), "packed rings must partition the grid");
    }

    #[test]
    fn packed_cells_carry_their_points() {
        // every packed slot must hold the coordinates of the point its id
        // names, and cell ranges must cover the cloud exactly once
        let pts = cloud(257, 21); // odd size: exercises the scalar tail
        let soa = crate::pointops::soa::PointsSoA::from_points(&pts);
        let mut g = GridStorage::default();
        g.build(&soa, 0.33);
        let mut seen = vec![false; pts.len()];
        for ring in 0..16 {
            g.ring([1.0, 1.0, 0.5], ring, |xs, ys, zs, ids| {
                for (l, &id) in ids.iter().enumerate() {
                    assert_eq!([xs[l], ys[l], zs[l]], pts[id as usize]);
                    assert!(!seen[id as usize], "point {id} packed twice");
                    seen[id as usize] = true;
                }
            });
        }
        assert!(seen.iter().all(|&s| s), "every point must be packed");
    }
}
