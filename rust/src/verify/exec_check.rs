//! Executor-lowering soundness (E rules): prove, statically, that the
//! closures `ScenePipeline::run` attaches per [`StageClass`] can never
//! deadlock or race on a [`crate::exec::Slot`].
//!
//! The verifier carries a declarative mirror of each stage class's slot
//! reads/writes — the same dataflow the closures in
//! `coordinator/pipeline.rs` perform — and checks it against the graph's
//! declared `deps`/`extra_deps`:
//!
//! - **E001** — a stage reads a slot whose producer is not covered by its
//!   *transitive* declared dependencies. Under `HostExec::Parallel` the
//!   executor may then run both concurrently: the read panics ("read
//!   before its producer ran") or observes a torn order. This is exactly
//!   the PR 2 `sa4_pm` merge bug (dropped cross-pipeline SA3 dependency),
//!   now caught mechanically — `rust/tests/verify.rs` re-introduces that
//!   bug in a fixture and pins this rule id.
//! - **E002** — two stages write the same slot: `Slot::set` on an
//!   already-full slot is a race regardless of scheduling order.
//! - **E003** — a stage reads a slot no stage produces and that is not one
//!   of the externally pre-seeded inputs (plain features for unpainted
//!   variants, carried-over 2D scores under skip-seg).

use std::collections::HashMap;

use super::{check_edges, Report, Severity};
use crate::graph::{StageClass, StageGraph};

const SEG_SCORES: &str = "seg scores";
const POINT_FEATURES: &str = "point features";

fn geo(ci: usize, l: usize) -> String {
    format!("chain {ci} geo[{l}]")
}

fn grp(ci: usize, l: usize) -> String {
    format!("chain {ci} groups[{l}]")
}

fn feats(ci: usize, l: usize) -> String {
    format!("chain {ci} feats[{l}]")
}

/// The slot dataflow of one stage class's compute closure, as (reads,
/// writes) over abstract slot names. Mirrors `ScenePipeline::run` — if a
/// closure there gains a new `Slot` read, add it here so the rule set
/// keeps proving dependency coverage.
fn slot_io(g: &StageGraph, class: StageClass) -> (Vec<String>, Vec<String>) {
    let n_chains = g.chains.len();
    let mut reads: Vec<String> = Vec::new();
    let mut writes: Vec<String> = Vec::new();
    match class {
        StageClass::Seg => writes.push(SEG_SCORES.into()),
        StageClass::Paint => {
            reads.push(SEG_SCORES.into());
            writes.push(POINT_FEATURES.into());
        }
        StageClass::SaPm { chain, level } => {
            if level > 0 {
                reads.push(geo(chain, level - 1));
            }
            let use_bias = g
                .chains
                .get(chain)
                .and_then(|c| c.levels.get(level))
                .is_some_and(|lv| lv.use_bias);
            if use_bias {
                reads.push(POINT_FEATURES.into()); // fg mask biases the FPS
            }
            writes.push(geo(chain, level));
            writes.push(grp(chain, level));
        }
        StageClass::SaNn { chain, level } => {
            reads.push(grp(chain, level));
            if level > 0 {
                reads.push(geo(chain, level - 1));
                reads.push(feats(chain, level - 1));
            } else {
                reads.push(POINT_FEATURES.into()); // level-0 gather
            }
            writes.push(feats(chain, level));
        }
        StageClass::Sa4Pm => {
            for ci in 0..n_chains {
                reads.push(geo(ci, 2));
            }
            if g.sa4_bias {
                reads.push(POINT_FEATURES.into()); // Table 10 "all SA layers"
            }
            writes.push("sa3 fused geo".into());
            writes.push("sa4 groups".into());
            writes.push("sa4 geo".into());
        }
        StageClass::Sa4Nn => {
            for ci in 0..n_chains {
                reads.push(feats(ci, 2));
            }
            reads.push("sa4 groups".into());
            reads.push("sa3 fused geo".into());
            writes.push("sa4 feats".into());
            writes.push("sa3 fused feats".into());
        }
        StageClass::FpInterp => {
            for ci in 0..n_chains {
                reads.push(geo(ci, 1));
                reads.push(feats(ci, 1));
            }
            reads.push("sa4 feats".into());
            reads.push("sa4 geo".into());
            reads.push("sa3 fused feats".into());
            reads.push("sa3 fused geo".into());
            writes.push("fp features".into());
            writes.push("seed xyz".into());
        }
        StageClass::FpFc => {
            reads.push("fp features".into());
            writes.push("seeds".into());
        }
        StageClass::Vote => {
            reads.push("seeds".into());
            reads.push("seed xyz".into());
            writes.push("votes".into());
        }
        StageClass::PropPm => {
            reads.push("votes".into());
            writes.push("proposal groups".into());
            writes.push("cluster xyz".into());
        }
        StageClass::Prop => {
            reads.push("proposal groups".into());
            reads.push("votes".into());
            writes.push("proposals".into());
        }
        StageClass::Decode => {
            reads.push("cluster xyz".into());
            reads.push("proposals".into());
            writes.push("detections".into());
        }
    }
    (reads, writes)
}

/// Slots `ScenePipeline::run` seeds before submitting the DAG, so a read
/// with no in-graph producer is still safe.
fn external_seeds(g: &StageGraph) -> Vec<String> {
    let painted = g.cfg().variant.painted();
    let mut seeds: Vec<String> = Vec::new();
    if painted && g.skip_seg() {
        seeds.push(SEG_SCORES.into()); // consecutive matching carries scores over
    }
    if !painted {
        seeds.push(POINT_FEATURES.into()); // plain features built up front
    }
    seeds
}

/// Rule family E over the `exec::DagExecutor` lowering of a graph. Edge
/// sanity (G001/G002) is re-checked first: dangling or forward deps make
/// the closure analysis itself unsound, so those diagnostics are returned
/// instead.
pub fn verify_exec(g: &StageGraph) -> Report {
    let mut r = Report::new();
    check_edges(g, &mut r);
    if r.has_errors() {
        return r;
    }

    let n = g.nodes.len();
    let io: Vec<(Vec<String>, Vec<String>)> =
        g.nodes.iter().map(|node| slot_io(g, node.class)).collect();

    // E002 — single-producer property
    let mut producer: HashMap<&str, usize> = HashMap::new();
    for (i, (_, writes)) in io.iter().enumerate() {
        for w in writes {
            if let Some(&first) = producer.get(w.as_str()) {
                r.push(
                    "E002",
                    Severity::Error,
                    format!("node {i} '{}'", g.nodes[i].spec.name),
                    format!(
                        "slot '{w}' written twice: also produced by node {first} '{}'",
                        g.nodes[first].spec.name
                    ),
                    "every slot has exactly one producer; split the output or rename the slot",
                );
            } else {
                producer.insert(w.as_str(), i);
            }
        }
    }

    // transitive dependency closure over deps ∪ extra_deps; indices are
    // all `< i` after check_edges, so one forward sweep suffices
    let mut reach: Vec<Vec<bool>> = Vec::with_capacity(n);
    for node in g.nodes.iter() {
        let mut row = vec![false; n];
        for &d in node.spec.deps.iter().chain(node.extra_deps.iter()) {
            row[d] = true;
            for (dst, &via) in row.iter_mut().zip(reach[d].iter()) {
                *dst = *dst || via;
            }
        }
        reach.push(row);
    }

    let seeds = external_seeds(g);
    for (i, (reads, _)) in io.iter().enumerate() {
        for s in reads {
            match producer.get(s.as_str()) {
                None => {
                    if !seeds.contains(s) {
                        r.push(
                            "E003",
                            Severity::Error,
                            format!("node {i} '{}'", g.nodes[i].spec.name),
                            format!("reads slot '{s}' that no stage produces and no seed fills"),
                            "add the producing stage or pre-seed the slot before submission",
                        );
                    }
                }
                Some(&p) => {
                    if !reach[i][p] {
                        r.push(
                            "E001",
                            Severity::Error,
                            format!("node {i} '{}'", g.nodes[i].spec.name),
                            format!(
                                "reads slot '{s}' produced by node {p} '{}' which its declared \
                                 deps do not (transitively) cover — a parallel executor may \
                                 run the read first",
                                g.nodes[p].spec.name
                            ),
                            "add the producer to deps (timeline) or extra_deps (host ordering)",
                        );
                    }
                }
            }
        }
    }
    r
}
