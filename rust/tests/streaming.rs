//! Streaming-path invariants: the temporal reuse cache must never change
//! what a FULL frame computes, the delta estimator must catch scene cuts
//! immediately, and the session-cache memory rule (S006) must price the
//! gateway's session map the way the verifier declares it.

use pointsplit::coordinator::{DetectorConfig, ScenePipeline, Schedule, Variant};
use pointsplit::data::stream::{generate_stream, StreamCfg};
use pointsplit::data::SYNRGBD;
use pointsplit::pointops::PointsSoA;
use pointsplit::runtime::Runtime;
use pointsplit::sim::DeviceKind;
use pointsplit::temporal::{
    session_footprint_bytes, DeltaCfg, FrameCache, FrameClass, StreamArtifacts,
};
use pointsplit::util::tensor::Tensor;
use pointsplit::verify;

fn pipelined() -> Schedule {
    Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu }
}

/// Satellite (a): after any run of REUSE/PARTIAL frames, a forced FULL
/// recompute (here: the scene cut opening shot 1, plus the cold first
/// frame) must be bit-identical to running the single-scene pipeline cold
/// on the same frame — the cache may only *observe* FULL frames, never
/// influence them.
#[test]
fn full_recompute_after_reuse_matches_cold_pipeline_bit_for_bit() {
    let rt = Runtime::synthetic();
    let cfg = DetectorConfig::new("synrgbd", Variant::PointSplit, true, pipelined());
    let pipe = ScenePipeline::new(&rt, cfg);
    for seed in [3u64, 19] {
        let scfg = StreamCfg { frames: 18, cut_period: 16, ..StreamCfg::default() };
        let stream = generate_stream(seed, &SYNRGBD, scfg);
        let mut cache = FrameCache::new(DeltaCfg::default(), 64 << 20);
        let mut classes = Vec::new();
        for f in &stream {
            let (out, class) = pipe.run_stream(&f.scene, seed, &mut cache).expect("stream frame");
            if f.meta.is_cut {
                assert_eq!(
                    class,
                    FrameClass::Full,
                    "seed {seed}: cut frame {} must be served FULL",
                    f.meta.index
                );
                let cold = pipe.run(&f.scene, seed).expect("cold frame");
                assert_eq!(
                    out.detections, cold.detections,
                    "seed {seed}: FULL frame {} detections diverged from the cold pipeline",
                    f.meta.index
                );
                assert_eq!(
                    out.timeline.total_ms.to_bits(),
                    cold.timeline.total_ms.to_bits(),
                    "seed {seed}: FULL frame {} timeline diverged",
                    f.meta.index
                );
                assert_eq!(out.peak_memory_mb.to_bits(), cold.peak_memory_mb.to_bits());
            }
            classes.push(class);
        }
        assert_eq!(classes[0], FrameClass::Full, "a cold session must open FULL");
        assert!(
            classes[1..16].iter().any(|c| *c != FrameClass::Full),
            "seed {seed}: expected REUSE/PARTIAL frames before the cut, got {classes:?}"
        );
    }
}

/// Satellite (d): across seeds, a scene-change cut is classified FULL by
/// the delta estimator on the very frame it happens — never served from a
/// stale anchor — while ordinary in-shot motion stays mostly reusable.
#[test]
fn delta_estimator_flags_scene_cuts_within_one_frame() {
    for seed in [1u64, 5, 9, 23] {
        let scfg = StreamCfg { frames: 33, cut_period: 8, ..StreamCfg::default() };
        let stream = generate_stream(seed, &SYNRGBD, scfg);
        let mut cache = FrameCache::new(DeltaCfg::default(), 64 << 20);
        let (mut non_cut, mut non_cut_full) = (0usize, 0usize);
        for f in &stream {
            let d = cache.classify(&f.scene.points);
            if f.meta.is_cut && f.meta.index > 0 {
                assert_eq!(
                    d.class,
                    FrameClass::Full,
                    "seed {seed}: cut at frame {} classified {:?} (changed_frac {:.3})",
                    f.meta.index,
                    d.class,
                    d.changed_frac
                );
            } else if !f.meta.is_cut {
                non_cut += 1;
                if d.class == FrameClass::Full {
                    non_cut_full += 1;
                }
            }
            // mirror the pipeline: FULL and PARTIAL frames re-anchor the cache
            if d.class != FrameClass::Reuse {
                let arts = StreamArtifacts {
                    seeds: Some(Tensor::zeros(vec![4, 3])),
                    seed_src: vec![0, 1, 2, 3],
                    points: PointsSoA::from_points(&f.scene.points),
                    ..Default::default()
                };
                cache.install(&f.scene.points, arts);
            }
        }
        assert!(
            non_cut_full * 2 < non_cut,
            "seed {seed}: {non_cut_full}/{non_cut} in-shot frames re-ran FULL — the \
             estimator is too jumpy for streaming to pay off"
        );
    }
}

/// The verifier's S006 rule: the session map's declared memory (sessions x
/// canonical per-session footprint) must fire if and only if it exceeds
/// the configured bound.
#[test]
fn s006_fires_only_when_declared_session_memory_exceeds_bound() {
    let per = session_footprint_bytes(2048, 256, 128, 11, 64);
    assert!(per > 0);
    let clean = verify::verify_session_cache(64, per, 64 << 20);
    assert!(
        !clean.fired("S006"),
        "default sizing (64 sessions x {per} B) must fit the default 64 MB bound"
    );
    assert!(clean.errors().is_empty());
    let over = verify::verify_session_cache(64, per, 8 << 20);
    assert!(over.fired("S006"), "64 sessions x {per} B must exceed an 8 MB bound");
    assert_eq!(over.errors().len(), 1);
}
