//! Quickstart: detect objects in one synthetic RGB-D scene with PointSplit
//! (INT8, GPU+EdgeTPU schedule) and print what each layer of the system did.
//!
//! ```bash
//! make artifacts            # once: train + AOT-export the networks
//! cargo run --release --example quickstart
//! ```

use pointsplit::coordinator::{DetectorConfig, ScenePipeline, Schedule, Variant};
use pointsplit::data::{generate_scene, SYNRGBD};
use pointsplit::eval::iou3d;
use pointsplit::runtime::Runtime;
use pointsplit::sim::DeviceKind;

fn main() -> anyhow::Result<()> {
    // 1. open the AOT artifacts (HLO text -> PJRT executables)
    let rt = Runtime::open("artifacts")?;
    println!("runtime: {} | {} artifacts", rt.platform(), rt.manifest.artifacts.len());

    // 2. one synthetic single-shot RGB-D scene (SUN RGB-D stand-in)
    let scene = generate_scene(42, &SYNRGBD);
    println!("scene: {} points, {} objects", scene.points.len(), scene.objects.len());

    // 3. PointSplit, INT8 (role-based group-wise quantization), two-lane
    //    pipelined schedule: point manipulation on "GPU", PointNets on "NPU"
    let cfg = DetectorConfig::new(
        "synrgbd",
        Variant::PointSplit,
        /*int8=*/ true,
        Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
    );
    let pipe = ScenePipeline::new(&rt, cfg);
    let out = pipe.run(&scene, 42)?;

    // 4. results: detections matched against ground truth
    println!("\n{:<12} {:>5}  {:>6}  match", "class", "score", "IoU");
    let gts = scene.gt_boxes();
    for d in out.detections.iter().filter(|d| d.score > 0.35) {
        let best = gts.iter().map(|g| iou3d(d, g)).fold(0.0, f64::max);
        println!(
            "{:<12} {:>5.2}  {:>6.2}  {}",
            rt.manifest.classes[d.class],
            d.score,
            best,
            if best > 0.25 { "HIT" } else { "--" }
        );
    }

    // 5. the system view: simulated two-lane timeline on the edge platform
    println!("\nsimulated on Jetson-Nano-GPU + EdgeTPU: {:.0} ms/scene", out.timeline.total_ms);
    println!(
        "  GPU  busy {:>5.0} ms   idle {:>5.0} ms",
        out.timeline.busy_ms.get(&DeviceKind::Gpu).unwrap_or(&0.0),
        out.timeline.idle_ms(DeviceKind::Gpu)
    );
    println!(
        "  NPU  busy {:>5.0} ms   idle {:>5.0} ms",
        out.timeline.busy_ms.get(&DeviceKind::EdgeTpu).unwrap_or(&0.0),
        out.timeline.idle_ms(DeviceKind::EdgeTpu)
    );
    println!("  host functional execution: {:.0} ms", out.host_ms);
    Ok(())
}
