//! Dynamic batcher: coalesce compatible queued requests up to a size /
//! timeout window before dispatch.
//!
//! Requests are compatible when they share a batch key (same dataset +
//! precision variant — one artifact set, one schedule). The batcher itself
//! holds no requests: it is a pure decision function over the admission
//! queue, invoked whenever the dispatch lane is free. That keeps admission
//! control honest (everything waiting is in the bounded queue) and makes the
//! policy trivially testable.
//!
//! Decision rule for the head-of-line key: dispatch now if the batch is full
//! or its oldest member has waited `max_wait_ms`; otherwise wait until one of
//! those becomes true. A partial batch therefore rides with whatever showed
//! up inside the window — the classic latency/throughput trade.

use super::loadgen::Request;
use super::queue::AdmissionQueue;

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Maximum requests coalesced into one dispatch.
    pub max_batch: usize,
    /// Maximum time the oldest compatible request may wait before the batch
    /// is forced out, ms.
    pub max_wait_ms: f64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 4, max_wait_ms: 25.0 }
    }
}

/// A formed batch ready for dispatch.
#[derive(Debug, Clone)]
pub struct Batch {
    pub key: usize,
    pub reqs: Vec<Request>,
    /// When the batch left the queue (dispatch decision time), ms.
    pub formed_ms: f64,
}

impl Batch {
    /// Earliest absolute deadline across members (drives SLO decisions).
    pub fn earliest_deadline_ms(&self) -> f64 {
        self.reqs.iter().map(|r| r.deadline_ms).fold(f64::INFINITY, f64::min)
    }

    /// Queueing delay of the oldest member at formation time.
    pub fn oldest_wait_ms(&self) -> f64 {
        self.reqs.iter().map(|r| self.formed_ms - r.arrival_ms).fold(0.0, f64::max)
    }
}

/// What the dispatcher should do right now.
#[derive(Debug, Clone)]
pub enum BatchDecision {
    /// Dispatch this batch immediately.
    Dispatch(Batch),
    /// Work is queued but still inside its coalescing window: re-evaluate at
    /// the given absolute time (or earlier, if an arrival lands first).
    WaitUntil(f64),
    /// Nothing queued.
    Idle,
}

/// Evaluate the batching rule against the queue at time `now_ms`.
///
/// The head-of-line request (priority order) picks the key; its cohort is
/// everything queued with the same key. `Dispatch` pops the cohort (up to
/// `max_batch`) off the queue; `WaitUntil` leaves the queue untouched.
pub fn decide(queue: &mut AdmissionQueue, policy: &BatchPolicy, now_ms: f64) -> BatchDecision {
    let Some(head) = queue.peek() else {
        return BatchDecision::Idle;
    };
    let key = head.key;
    let ready = queue.count_key(key) >= policy.max_batch.max(1);
    let oldest = queue.oldest_arrival_for_key(key).expect("head key present");
    let deadline_to_form = oldest + policy.max_wait_ms;
    if ready || now_ms >= deadline_to_form {
        let reqs = queue.pop_key(key, policy.max_batch.max(1));
        debug_assert!(!reqs.is_empty());
        BatchDecision::Dispatch(Batch { key, reqs, formed_ms: now_ms })
    } else {
        BatchDecision::WaitUntil(deadline_to_form)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, key: usize, arrival: f64) -> Request {
        Request {
            id,
            arrival_ms: arrival,
            deadline_ms: arrival + 500.0,
            seed: id,
            class: 0,
            key,
            client: 0,
        }
    }

    fn queue_with(reqs: Vec<Request>) -> AdmissionQueue {
        let mut q = AdmissionQueue::new(64, 1);
        for r in reqs {
            q.offer(r);
        }
        q
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let mut q = queue_with((0..4).map(|i| req(i, 0, i as f64)).collect());
        let policy = BatchPolicy { max_batch: 4, max_wait_ms: 100.0 };
        match decide(&mut q, &policy, 3.5) {
            BatchDecision::Dispatch(b) => {
                assert_eq!(b.reqs.len(), 4);
                assert_eq!(b.key, 0);
                assert!(q.is_empty());
            }
            other => panic!("expected dispatch, got {other:?}"),
        }
    }

    #[test]
    fn partial_batch_waits_then_flushes() {
        let mut q = queue_with(vec![req(0, 0, 10.0), req(1, 0, 12.0)]);
        let policy = BatchPolicy { max_batch: 4, max_wait_ms: 25.0 };
        match decide(&mut q, &policy, 14.0) {
            BatchDecision::WaitUntil(t) => assert!((t - 35.0).abs() < 1e-9),
            other => panic!("expected wait, got {other:?}"),
        }
        assert_eq!(q.len(), 2, "waiting must not consume the queue");
        match decide(&mut q, &policy, 35.0) {
            BatchDecision::Dispatch(b) => {
                assert_eq!(b.reqs.len(), 2);
                assert!((b.oldest_wait_ms() - 25.0).abs() < 1e-9);
            }
            other => panic!("expected dispatch, got {other:?}"),
        }
    }

    #[test]
    fn cohort_limited_to_head_key() {
        let mut q = queue_with(vec![req(0, 1, 0.0), req(1, 0, 1.0), req(2, 1, 2.0)]);
        let policy = BatchPolicy { max_batch: 2, max_wait_ms: 5.0 };
        match decide(&mut q, &policy, 10.0) {
            BatchDecision::Dispatch(b) => {
                assert_eq!(b.key, 1, "head-of-line request picks the key");
                assert_eq!(b.reqs.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
            }
            other => panic!("expected dispatch, got {other:?}"),
        }
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn idle_on_empty() {
        let mut q = AdmissionQueue::new(4, 1);
        assert!(matches!(decide(&mut q, &BatchPolicy::default(), 0.0), BatchDecision::Idle));
    }

    #[test]
    fn earliest_deadline_is_min() {
        let b = Batch { key: 0, reqs: vec![req(0, 0, 5.0), req(1, 0, 1.0)], formed_ms: 20.0 };
        assert!((b.earliest_deadline_ms() - 501.0).abs() < 1e-9);
        assert!((b.oldest_wait_ms() - 19.0).abs() < 1e-9);
    }
}
