//! Consecutive vs concurrent matching (paper §3.2).
//!
//! PointPainting's original latency mitigation reuses the *previous* frame's
//! 2D segmentation ("consecutive matching") — cheap, but wrong whenever the
//! camera moves. PointSplit's answer is "concurrent matching": run 2D and 3D
//! on the *current* frame in parallel on GPU+NPU.
//!
//! This driver simulates a camera panning through a scene sequence: each
//! frame is the same room viewed from a slightly rotated camera. It compares
//! three policies on latency AND accuracy:
//!
//!   1. concurrent  — PointSplit: fresh segmentation every frame, overlapped
//!   2. consecutive — segmentation every k-th frame, reused in between
//!   3. sequential  — fresh segmentation, naive Fig. 2 schedule
//!
//! ```bash
//! cargo run --release --example consecutive_matching -- [frames]
//! ```

use pointsplit::bench::Table;
use pointsplit::coordinator::{DetectorConfig, ScenePipeline, Schedule, Variant};
use pointsplit::data::{generate_scene, SYNRGBD};
use pointsplit::eval::{eval_map, Detection};
use pointsplit::runtime::Runtime;
use pointsplit::sim::DeviceKind;
use pointsplit::util::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let frames: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(24);
    let rt = Runtime::open("artifacts")?;
    let seq = Schedule::Sequential { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu };
    let par = Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu };

    // "camera pan": consecutive frames are *different* generated scenes —
    // the adversarial case for stale segmentation (view change between
    // frames, which the paper says consecutive matching cannot survive)
    let scenes: Vec<_> = (0..frames).map(|i| generate_scene(910_000 + i as u64, &SYNRGBD)).collect();
    let gts: Vec<_> = scenes.iter().map(|s| s.gt_boxes()).collect();

    let mut table =
        Table::new(&["policy", "mAP@0.25", "sim ms/frame", "NPU seg runs"]);

    // 1. concurrent matching (PointSplit, fresh seg each frame)
    {
        let pipe =
            ScenePipeline::new(&rt, DetectorConfig::new("synrgbd", Variant::PointSplit, true, par));
        let mut dets = Vec::new();
        let mut lat = 0.0;
        for (i, scene) in scenes.iter().enumerate() {
            let out = pipe.run(scene, i as u64)?;
            lat += out.timeline.total_ms;
            dets.extend(out.detections.into_iter().map(|b| Detection { scene: i, b }));
        }
        let r = eval_map(&dets, &gts, rt.manifest.num_class(), 0.25);
        table.row(vec![
            "concurrent (PointSplit)".into(),
            format!("{:.1}", r.map * 100.0),
            format!("{:.0}", lat / frames as f64),
            format!("{frames}"),
        ]);
    }

    // 2. consecutive matching: segment every k-th frame, reuse in between
    for k in [2usize, 4] {
        let pipe = ScenePipeline::new(
            &rt,
            DetectorConfig::new("synrgbd", Variant::PointPainting, true, seq),
        );
        let mut dets = Vec::new();
        let mut lat = 0.0;
        let mut carried: Option<Tensor> = None;
        let mut seg_runs = 0;
        for (i, scene) in scenes.iter().enumerate() {
            let reuse = i % k != 0;
            let prev = if reuse { carried.as_ref() } else { None };
            if !reuse {
                seg_runs += 1;
            }
            let (out, scores) = pipe.run_with_scores(scene, i as u64, prev)?;
            if !reuse {
                carried = scores;
            }
            lat += out.timeline.total_ms;
            dets.extend(out.detections.into_iter().map(|b| Detection { scene: i, b }));
        }
        let r = eval_map(&dets, &gts, rt.manifest.num_class(), 0.25);
        table.row(vec![
            format!("consecutive (reuse, k={k})"),
            format!("{:.1}", r.map * 100.0),
            format!("{:.0}", lat / frames as f64),
            format!("{seg_runs}"),
        ]);
    }

    // 3. sequential fresh segmentation (Fig. 2 baseline)
    {
        let pipe = ScenePipeline::new(
            &rt,
            DetectorConfig::new("synrgbd", Variant::PointPainting, true, seq),
        );
        let mut dets = Vec::new();
        let mut lat = 0.0;
        for (i, scene) in scenes.iter().enumerate() {
            let out = pipe.run(scene, i as u64)?;
            lat += out.timeline.total_ms;
            dets.extend(out.detections.into_iter().map(|b| Detection { scene: i, b }));
        }
        let r = eval_map(&dets, &gts, rt.manifest.num_class(), 0.25);
        table.row(vec![
            "sequential (fresh seg)".into(),
            format!("{:.1}", r.map * 100.0),
            format!("{:.0}", lat / frames as f64),
            format!("{frames}"),
        ]);
    }

    table.print(&format!(
        "consecutive vs concurrent matching over a {frames}-frame pan (view changes every frame)"
    ));
    println!(
        "\npaper §3.2: reusing stale segmentation is \"vulnerable to the difference\n\
         between the current and previous scenes and cannot be applied to\n\
         single-shot detection\" — here every frame changes view, so the reuse\n\
         rows trade accuracy for their latency win, while concurrent matching\n\
         (PointSplit) gets the latency without the staleness."
    );
    Ok(())
}
