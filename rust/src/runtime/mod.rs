//! PJRT runtime: loads AOT-compiled HLO-text artifacts and executes them.
//!
//! This is the only place the `xla` crate is touched. The interchange format
//! is HLO **text** (see python/compile/export_utils.py and DESIGN.md): jax
//! ≥ 0.5 serializes protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids and round-trips cleanly.
//!
//! The [`Runtime`] owns one PJRT CPU client plus a lazily-compiled executable
//! cache keyed by artifact name; [`Manifest`] mirrors
//! `artifacts/manifest.json` (shapes, workload descriptors, model constants).
//!
//! # Surrogate fallback
//!
//! When the PJRT backend is the vendored stub (it reports "PJRT
//! unavailable" at compile time), [`Runtime::run`] falls back to the
//! deterministic host [`surrogate`] so the functional pipeline — detections,
//! serving, determinism tests, benches — works offline. A runtime opened on
//! a real `xla-rs` build never touches the surrogate, and real backend
//! errors (missing files, bad HLO) still propagate. [`Runtime::synthetic`]
//! builds a runtime that needs no artifacts directory at all: synthetic
//! manifest + surrogate execution.

pub mod gemm;
pub mod manifest;
pub mod surrogate;

pub use manifest::{ArtifactMeta, Manifest};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, Once, PoisonError};

use anyhow::{anyhow, Context, Result};

use crate::quant::QuantSpec;
use crate::util::tensor::Tensor;

/// Where a [`Runtime`] came from — lets worker threads open their own
/// equivalent runtime (PJRT handles are not `Send` with the real backend).
#[derive(Debug, Clone)]
pub enum RuntimeSource {
    /// `Runtime::open` on an artifacts directory.
    Artifacts(PathBuf),
    /// `Runtime::synthetic()` — synthetic manifest, surrogate execution.
    Synthetic,
}

impl RuntimeSource {
    pub fn open(&self) -> Result<Runtime> {
        match self {
            RuntimeSource::Artifacts(dir) => Runtime::open(dir),
            RuntimeSource::Synthetic => Ok(Runtime::synthetic()),
        }
    }
}

/// PJRT-backed executor for the AOT artifacts.
pub struct Runtime {
    client: Option<xla::PjRtClient>,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    source: RuntimeSource,
    /// flips once PJRT reports itself unavailable (the vendored stub);
    /// later calls skip straight to the surrogate
    surrogate_only: AtomicBool,
}

fn note_surrogate() {
    static NOTE: Once = Once::new();
    NOTE.call_once(|| {
        eprintln!(
            "note: PJRT backend unavailable (vendored `xla` stub) — executing NN stages \
             on the deterministic host surrogate"
        );
    });
}

impl Runtime {
    /// Open `artifacts/` (must contain manifest.json) on the PJRT CPU client.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
        let manifest = Manifest::parse(&text)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client: Some(client),
            source: RuntimeSource::Artifacts(dir.clone()),
            dir,
            manifest,
            cache: Mutex::new(HashMap::new()),
            surrogate_only: AtomicBool::new(false),
        })
    }

    /// Artifact-free runtime: [`Manifest::synthetic`] + surrogate execution.
    /// Everything the coordinator can reference resolves and executes
    /// deterministically; no filesystem access, no PJRT.
    pub fn synthetic() -> Self {
        Runtime {
            client: None,
            dir: PathBuf::new(),
            manifest: Manifest::synthetic(),
            cache: Mutex::new(HashMap::new()),
            source: RuntimeSource::Synthetic,
            surrogate_only: AtomicBool::new(true),
        }
    }

    /// How to open another runtime equivalent to this one (for worker
    /// threads; PJRT handles are not `Send` with the real backend).
    pub fn source(&self) -> RuntimeSource {
        self.source.clone()
    }

    pub fn platform(&self) -> String {
        match &self.client {
            Some(c) => c.platform_name(),
            None => "host-surrogate".to_string(),
        }
    }

    /// Artifacts directory this runtime loads from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self
            .cache
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .get(name)
        {
            return Ok(e.clone());
        }
        let client = self
            .client
            .as_ref()
            .ok_or_else(|| anyhow!("PJRT unavailable (synthetic runtime)"))?;
        let meta = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling artifact '{name}': {e:?}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of executables compiled so far (for metrics/tests).
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap_or_else(PoisonError::into_inner).len()
    }

    /// Execute an artifact on f32 tensors at its manifest-declared quant
    /// spec. See [`Runtime::run_with_spec`].
    pub fn run(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.run_with_spec(name, inputs, None)
    }

    /// Execute an artifact on f32 tensors. Inputs are validated against the
    /// manifest shapes; outputs come back as a tuple of tensors. Falls back
    /// to the deterministic host surrogate when PJRT is the vendored stub.
    ///
    /// `spec` is the QuantScheme layer's entry point: an explicit per-stage
    /// quant spec overriding the manifest default for this call (the
    /// serving degrade path runs backbone artifacts at granularities their
    /// names do not encode). It only affects the surrogate — real PJRT
    /// executables have their numerics baked in at export time.
    pub fn run_with_spec(
        &self,
        name: &str,
        inputs: &[&Tensor],
        spec: Option<&QuantSpec>,
    ) -> Result<Vec<Tensor>> {
        self.run_with_spec_t(name, inputs, spec, 1)
    }

    /// [`Runtime::run_with_spec`] with a row-tile thread budget for the
    /// surrogate GEMM kernels (the pipeline passes its per-scene host
    /// thread budget through; results are bit-identical for any count).
    /// The budget only affects the surrogate — real PJRT executables
    /// thread themselves.
    pub fn run_with_spec_t(
        &self,
        name: &str,
        inputs: &[&Tensor],
        spec: Option<&QuantSpec>,
        threads: usize,
    ) -> Result<Vec<Tensor>> {
        let meta = self.validated_meta(name, inputs)?;
        if !self.surrogate_only.load(Ordering::Relaxed) {
            match self.run_pjrt(name, inputs) {
                Ok(out) => return Ok(out),
                // the stub fails with this exact marker; real backend
                // errors (missing file, bad HLO, exec fault) propagate
                Err(e) if format!("{e:#}").contains("PJRT unavailable") => {
                    self.surrogate_only.store(true, Ordering::Relaxed);
                    note_surrogate();
                }
                Err(e) => return Err(e),
            }
        }
        surrogate::run_with_spec_t(&self.manifest, &meta, inputs, spec, threads)
    }

    /// Execute one artifact over a batch of k scenes' inputs as a single
    /// fused GEMM ([`surrogate::run_batch_with_spec`]); returns one output
    /// tensor per scene, in order. Each scene contributes the artifact's
    /// first input, validated against the manifest shape. On a real PJRT
    /// backend the executables run sequentially per scene (their batch
    /// dimension is baked in at export time); the surrogate fuses.
    pub fn run_batch_with_spec(
        &self,
        name: &str,
        inputs: &[&Tensor],
        spec: Option<&QuantSpec>,
        threads: usize,
    ) -> Result<Vec<Tensor>> {
        let meta = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        let shape = meta
            .input_shapes
            .first()
            .ok_or_else(|| anyhow!("artifact '{name}' declares no inputs"))?;
        for (i, t) in inputs.iter().enumerate() {
            if &t.shape != shape {
                return Err(anyhow!(
                    "artifact '{name}' batch input {i}: shape {:?} != manifest {:?}",
                    t.shape,
                    shape
                ));
            }
        }
        if !self.surrogate_only.load(Ordering::Relaxed) {
            let mut outs = Vec::with_capacity(inputs.len());
            let mut pjrt_ok = true;
            for t in inputs {
                match self.run_pjrt(name, &[t]) {
                    Ok(mut out) => outs.push(out.swap_remove(0)),
                    Err(e) if format!("{e:#}").contains("PJRT unavailable") => {
                        self.surrogate_only.store(true, Ordering::Relaxed);
                        note_surrogate();
                        pjrt_ok = false;
                        break;
                    }
                    Err(e) => return Err(e),
                }
            }
            if pjrt_ok {
                return Ok(outs);
            }
        }
        surrogate::run_batch_with_spec(&self.manifest, &meta, inputs, spec, threads)
    }

    fn validated_meta(&self, name: &str, inputs: &[&Tensor]) -> Result<ArtifactMeta> {
        let meta = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        if inputs.len() != meta.input_shapes.len() {
            return Err(anyhow!(
                "artifact '{name}': expected {} inputs, got {}",
                meta.input_shapes.len(),
                inputs.len()
            ));
        }
        for (i, (t, s)) in inputs.iter().zip(meta.input_shapes.iter()).enumerate() {
            if &t.shape != s {
                return Err(anyhow!(
                    "artifact '{name}' input {i}: shape {:?} != manifest {:?}",
                    t.shape,
                    s
                ));
            }
        }
        Ok(meta)
    }

    /// The real PJRT execution path (requires a working `xla-rs` backend).
    fn run_pjrt(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let exe = self.executable(name)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("literal reshape: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("executing '{name}': {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of '{name}': {e:?}"))?;
        // exports lower with return_tuple=True
        let parts = result.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit.shape().map_err(|e| anyhow!("shape: {e:?}"))?;
                let dims: Vec<usize> = match shape {
                    xla::Shape::Array(a) => a.dims().iter().map(|&d| d as usize).collect(),
                    _ => return Err(anyhow!("non-array output")),
                };
                let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
                Ok(Tensor::new(dims, data))
            })
            .collect()
    }

    /// Compile every artifact in the manifest; returns (ok, failures).
    pub fn check_all(&self) -> (usize, Vec<(String, String)>) {
        let mut ok = 0;
        let mut failures = Vec::new();
        let names: Vec<String> =
            self.manifest.artifacts.iter().map(|a| a.name.clone()).collect();
        for name in names {
            match self.executable(&name) {
                Ok(_) => ok += 1,
                Err(e) => failures.push((name, format!("{e:#}"))),
            }
        }
        (ok, failures)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_runtime_executes_every_artifact_role() {
        let rt = Runtime::synthetic();
        assert_eq!(rt.platform(), "host-surrogate");
        for name in [
            "synrgbd_seg_int8",
            "synrgbd_pointsplit_sa1_half_int8",
            "synrgbd_pointsplit_fp_fc_int8",
            "synrgbd_pointsplit_vote_int8_role",
            "synrgbd_pointsplit_prop_int8_role",
        ] {
            let meta = rt.manifest.artifact(name).expect(name).clone();
            let inputs: Vec<Tensor> = meta
                .input_shapes
                .iter()
                .map(|s| Tensor::zeros(s.clone()))
                .collect();
            let refs: Vec<&Tensor> = inputs.iter().collect();
            let out = rt.run(name, &refs).expect(name);
            assert!(!out.is_empty());
        }
    }

    #[test]
    fn synthetic_runtime_validates_shapes() {
        let rt = Runtime::synthetic();
        let bad = Tensor::zeros(vec![1, 2, 3]);
        assert!(rt.run("synrgbd_seg_int8", &[&bad]).is_err());
        assert!(rt.run("no_such_artifact", &[&bad]).is_err());
    }

    #[test]
    fn batch_run_validates_and_matches_sequential() {
        let rt = Runtime::synthetic();
        let name = "synrgbd_pointsplit_vote_fp32";
        let meta = rt.manifest.artifact(name).expect(name).clone();
        let xs: Vec<Tensor> = (0..2)
            .map(|i| {
                let mut t = Tensor::zeros(meta.input_shapes[0].clone());
                for (k, v) in t.data.iter_mut().enumerate() {
                    *v = ((k + 1) as f32 * 0.001) + i as f32 * 0.1;
                }
                t
            })
            .collect();
        let refs: Vec<&Tensor> = xs.iter().collect();
        let fused = rt.run_batch_with_spec(name, &refs, None, 2).expect("batch");
        assert_eq!(fused.len(), 2);
        for (x, y) in xs.iter().zip(fused.iter()) {
            let solo = rt.run(name, &[x]).expect("solo").remove(0);
            assert_eq!(&solo, y);
        }
        let bad = Tensor::zeros(vec![1, 2, 3]);
        assert!(rt.run_batch_with_spec(name, &[&bad], None, 1).is_err());
    }

    #[test]
    fn source_reopens_equivalent_runtime() {
        let rt = Runtime::synthetic();
        let rt2 = rt.source().open().expect("reopen synthetic");
        assert_eq!(rt.manifest.artifacts.len(), rt2.manifest.artifacts.len());
    }
}
