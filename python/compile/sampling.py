"""Point-manipulation ops in JAX (L2): FPS, biased FPS, ball query, 3-NN FP.

These are the operations the paper identifies as *not NPU-executable* — at
inference they run in Rust (`rust/src/pointops/`), but the training graph and
the pure-python reference pipeline need jittable versions. The Rust port is
numerics-checked against these in the parity tests (Table 3 bench).

Biased FPS implements paper Eq. 1: d(p1, p2) = w * ||p1 - p2|| with
w = w0 when either endpoint is foreground. In the incremental FPS update the
pair factor is f_ij = 1 + (w0 - 1) * (fg_i OR fg_j).
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from .kernels.pairwise import pairwise_dist2_pallas
from .kernels.ref import pairwise_dist2_ref


def fps(
    xyz: jnp.ndarray,
    m: int,
    fg: jnp.ndarray | None = None,
    w0: float = 1.0,
    start: int = 0,
) -> jnp.ndarray:
    """(Biased) farthest point sampling.

    xyz: (N, 3); fg: (N,) float {0,1} foreground mask (from painted scores);
    w0: Eq. 1 weight. Returns (m,) int32 indices. Deterministic: starts from
    `start` (matches the Rust implementation). The SA-bias pipeline starts at
    a different point than SA-normal so the two views stay decorrelated even
    where both use regular FPS.
    """
    n = xyz.shape[0]
    if fg is None:
        fg = jnp.zeros((n,), jnp.float32)
    fg = fg.astype(jnp.float32)

    def body(i, state):
        min_d2, last, out = state
        d2 = jnp.sum((xyz - xyz[last]) ** 2, axis=1)
        # pair weight^2: w0^2 if either endpoint is foreground (Eq. 1)
        either = fg + fg[last] - fg * fg[last]
        f2 = (1.0 + (w0 - 1.0) * either) ** 2
        min_d2 = jnp.minimum(min_d2, d2 * f2)
        nxt = jnp.argmax(min_d2).astype(jnp.int32)
        out = out.at[i].set(nxt)
        return min_d2, nxt, out

    out = jnp.zeros((m,), jnp.int32)
    init = (jnp.full((n,), jnp.inf, jnp.float32), jnp.int32(start), out.at[0].set(start))
    _, _, out = jax.lax.fori_loop(1, m, body, init)
    return out


def ball_query(
    centers: jnp.ndarray,
    xyz: jnp.ndarray,
    radius: float,
    k: int,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Nearest-K-within-radius grouping.

    centers: (M, 3), xyz: (N, 3) -> (M, K) int32 indices. Out-of-radius slots
    are filled with the nearest in-radius point (PointNet++ convention of
    repeating a valid member); if a ball is empty the nearest point is used.
    """
    dist2 = (
        pairwise_dist2_pallas(centers, xyz) if use_pallas else pairwise_dist2_ref(centers, xyz)
    )
    big = jnp.float32(1e10)
    masked = jnp.where(dist2 <= radius * radius, dist2, big)
    neg, idx = jax.lax.top_k(-masked, k)  # nearest within radius first
    valid = -neg < big * 0.5
    # fill invalid slots with the ball's first (nearest) member
    fallback_in = idx[:, :1]
    fallback_any = jnp.argmin(dist2, axis=1, keepdims=True).astype(idx.dtype)
    fallback = jnp.where(valid[:, :1], fallback_in, fallback_any)
    return jnp.where(valid, idx, fallback).astype(jnp.int32)


def group_features(
    xyz: jnp.ndarray, feats: jnp.ndarray | None, centers_idx: jnp.ndarray, group_idx: jnp.ndarray
) -> jnp.ndarray:
    """Gather grouped features: relative xyz ++ point features.

    xyz: (N, 3), feats: (N, C) or None, centers_idx: (M,), group_idx: (M, K)
    -> (M, K, 3 + C).
    """
    centers = xyz[centers_idx]  # (M, 3)
    pts = xyz[group_idx]  # (M, K, 3)
    rel = pts - centers[:, None, :]
    if feats is None:
        return rel
    return jnp.concatenate([rel, feats[group_idx]], axis=-1)


def three_nn_interpolate(
    dst_xyz: jnp.ndarray, src_xyz: jnp.ndarray, src_feats: jnp.ndarray
) -> jnp.ndarray:
    """Feature propagation: inverse-distance weighted 3-NN interpolation.

    dst_xyz: (Nd, 3) fine points, src_xyz: (Ns, 3) coarse points,
    src_feats: (Ns, C) -> (Nd, C).
    """
    d2 = pairwise_dist2_ref(dst_xyz, src_xyz)
    neg, idx = jax.lax.top_k(-d2, 3)
    w = 1.0 / jnp.maximum(-neg, 1e-8)
    w = w / jnp.sum(w, axis=1, keepdims=True)
    return jnp.sum(src_feats[idx] * w[..., None], axis=1)


@partial(jax.jit, static_argnums=(1,))
def fps_jit(xyz: jnp.ndarray, m: int) -> jnp.ndarray:
    return fps(xyz, m)


def random_split(n: int, key: jax.Array) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """RandomSplit baseline: permute indices and split the point set in half."""
    perm = jax.random.permutation(key, n)
    return perm[: n // 2], perm[n // 2 :]
