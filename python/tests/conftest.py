import os
import sys

# make `compile` importable regardless of pytest invocation directory
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

# keep any in-test training tiny
os.environ.setdefault("POINTSPLIT_SEG_STEPS", "6")
os.environ.setdefault("POINTSPLIT_DET_STEPS", "6")
os.environ.setdefault("POINTSPLIT_POOL", "12")
