//! Serving under overload: latency and goodput vs offered load (0.5x–2x of
//! steady-state capacity) for Poisson and bursty arrivals, with and without
//! the SLO-degradation policy.
//!
//! Expected shape: below capacity the two policies coincide (nothing to
//! degrade); past capacity the no-policy baseline's p99 and goodput collapse
//! together (the accelerators burn time on already-dead requests), while the
//! degrade policy sheds the unsavable, switches the rest to the INT8
//! fast path, and holds goodput near capacity. Runs entirely on the
//! simulated clock with the synthetic manifest — no artifacts needed.
//!
//! ```bash
//! cargo bench --bench serving_overload
//! POINTSPLIT_BENCH_SCENES=120 cargo bench --bench serving_overload   # longer windows
//! ```

#[allow(dead_code)]
mod common;

use pointsplit::bench::{write_bench_json, Table};
use pointsplit::coordinator::{DetectorConfig, Schedule, Variant};
use pointsplit::serving::{
    run_traffic, ArrivalPattern, BatchPolicy, LoadGen, ServeTrafficReport, ServicePlanner,
    SloPolicy, TrafficScenario,
};
use pointsplit::sim::DeviceKind;
use pointsplit::util::json::Json;

fn run_one(
    planner: &ServicePlanner,
    cfg: &DetectorConfig,
    pattern: ArrivalPattern,
    duration_s: f64,
    policy: SloPolicy,
) -> ServeTrafficReport {
    let sc = TrafficScenario {
        name: format!("{}-{}", pattern.name(), policy.name()),
        configs: vec![cfg.clone()],
        num_points: 2048,
        load: LoadGen::simple(pattern, duration_s * 1000.0, 1_000.0, 4242),
        queue_capacity: 64,
        batch: BatchPolicy { max_batch: 4, max_wait_ms: 25.0 },
        policy,
    };
    run_traffic(&sc, planner, None).expect("synthetic planner costs every config")
}

fn main() {
    let planner = ServicePlanner::synthetic();
    let cfg = DetectorConfig::new(
        "synrgbd",
        Variant::PointSplit,
        true,
        Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
    );
    let cap = planner.capacity_rps(&cfg, 2048, 4).expect("capacity");
    // reuse the shared bench budget knob: here it scales the traffic window
    let duration_s = common::scene_budget(40) as f64;
    println!(
        "serving_overload: PointSplit INT8 GPU+EdgeTPU, capacity {cap:.2} rps at batch 4, \
         {duration_s:.0}s simulated windows, deadline 1000 ms\n"
    );

    let mut scenarios: Vec<Json> = Vec::new();
    for pattern_name in ["poisson", "bursty"] {
        let mut t = Table::new(&[
            "load",
            "offered rps",
            "p99 ms (none)",
            "p99 ms (slo)",
            "goodput (none)",
            "goodput (slo)",
            "SLO% (none)",
            "SLO% (slo)",
            "shed",
            "degraded",
        ]);
        let mut worst: Option<(ServeTrafficReport, ServeTrafficReport)> = None;
        for mult in [0.5, 0.75, 1.0, 1.5, 2.0] {
            let rate = cap * mult;
            let pattern = match pattern_name {
                "poisson" => ArrivalPattern::Poisson { rate_rps: rate },
                _ => ArrivalPattern::Bursty {
                    base_rps: rate * 0.4,
                    burst_rps: rate * 2.5,
                    mean_burst_ms: 2_000.0,
                    mean_calm_ms: 6_000.0,
                },
            };
            let none = run_one(&planner, &cfg, pattern, duration_s, SloPolicy::None);
            let slo = run_one(&planner, &cfg, pattern, duration_s, SloPolicy::Degrade);
            t.row(vec![
                format!("{mult:.2}x"),
                format!("{:.1}", none.offered_rps),
                format!("{:.0}", none.latency_ms.p99),
                format!("{:.0}", slo.latency_ms.p99),
                format!("{:.2}", none.goodput_rps),
                format!("{:.2}", slo.goodput_rps),
                format!("{:.1}", 100.0 * none.slo_attainment),
                format!("{:.1}", 100.0 * slo.slo_attainment),
                slo.shed_slo.to_string(),
                slo.degraded.to_string(),
            ]);
            scenarios.push(Json::obj(vec![
                ("pattern", Json::Str(pattern_name.to_string())),
                ("load_mult", Json::Num(mult)),
                ("offered_rps", Json::Num(none.offered_rps)),
                ("p99_ms_none", Json::Num(none.latency_ms.p99)),
                ("p99_ms_slo", Json::Num(slo.latency_ms.p99)),
                ("goodput_rps_none", Json::Num(none.goodput_rps)),
                ("goodput_rps_slo", Json::Num(slo.goodput_rps)),
                ("slo_attainment_none", Json::Num(none.slo_attainment)),
                ("slo_attainment_slo", Json::Num(slo.slo_attainment)),
                ("shed_slo", Json::Num(slo.shed_slo as f64)),
                ("degraded", Json::Num(slo.degraded as f64)),
            ]));
            if mult == 2.0 {
                worst = Some((none, slo));
            }
        }
        t.print(&format!(
            "serving overload — {pattern_name} arrivals, none vs degrade+shed policy"
        ));
        if let Some((none, slo)) = worst {
            let gain = slo.goodput_rps / none.goodput_rps.max(1e-9);
            println!(
                "at 2.0x overload ({pattern_name}): goodput {:.2} -> {:.2} rps ({gain:.2}x), \
                 SLO {:.1}% -> {:.1}%  [{}]",
                none.goodput_rps,
                slo.goodput_rps,
                100.0 * none.slo_attainment,
                100.0 * slo.slo_attainment,
                if slo.goodput_rps > none.goodput_rps { "OK: policy wins" } else { "REGRESSION" }
            );
        }
        println!();
    }

    let payload = Json::obj(vec![
        ("bench", Json::Str("serving_overload".to_string())),
        ("capacity_rps", Json::Num(cap)),
        ("duration_s", Json::Num(duration_s)),
        ("deadline_ms", Json::Num(1000.0)),
        ("batch_max", Json::Num(4.0)),
        ("scenarios", Json::Arr(scenarios)),
    ]);
    write_bench_json("BENCH_serving.json", &payload);
}
