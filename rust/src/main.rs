//! PointSplit launcher CLI.
//!
//! ```text
//! pointsplit check    [--artifacts DIR]
//!     compile every HLO artifact through PJRT and report failures
//! pointsplit detect   [--artifacts DIR] [--dataset synrgbd] [--variant pointsplit]
//!                     [--int8] [--schedule gpu+edgetpu] [--seed N]
//!     run one scene end-to-end; print detections + simulated timeline
//! pointsplit serve    [--scenes 32] [--workers 4] [... detect flags]
//!     multi-scene request loop; print mAP + latency/memory report
//! pointsplit serve-traffic [--pattern poisson|bursty|diurnal|all] [--load 0.8 | --rate RPS]
//!                     [--duration-s 30] [--deadline-ms 1000]
//!                     [--policy degrade|stale-tracks|shed|none] [--queue-cap 64]
//!                     [--batch-max 4] [--batch-wait-ms 25] [--hi-frac 0] [--clients 0]
//!                     [--functional] [--exec-workers N] [... detect flags]
//!     open-loop traffic gateway on the simulated clock; print a
//!     ServeTrafficReport per arrival pattern (see docs/SERVING.md);
//!     --clients > 0 tags arrivals as streaming sessions (docs/STREAMING.md)
//! pointsplit serve-stream [--frames 32] [--cut-period 16] [--session-cache-mb 4]
//!                     [--seed N] [... detect flags]
//!     temporal streaming demo: evolve one synthetic room under seeded
//!     ego-motion, run every frame through a warm per-session FrameCache,
//!     and compare against the cold per-frame pipeline (docs/STREAMING.md)
//! pointsplit serve-cluster [--boxes "gpu+edgetpu:2,gpu:1,cpu+edgetpu:1"] [--configs 2]
//!                     [--router affinity|random|least-loaded] [--pattern poisson|bursty|diurnal]
//!                     [--load 0.8 | --rate RPS] [--duration-s 30] [--deadline-ms 1000]
//!                     [--policy degrade|stale-tracks|shed|none] [--queue-cap 32]
//!                     [--batch-max 4] [--batch-wait-ms 25] [--clients 0] [--kill "1@15"]
//!                     [--slow "0@10x3:5"]
//!                     [--autoscale] [--scale-max 16] [--json PATH] [... detect flags]
//!     fleet-scale gateway: shard traffic across heterogeneous edge boxes,
//!     each planned by the placement search; print a ClusterReport with
//!     per-box rows and the fault/scaling event log (see docs/CLUSTER.md)
//! pointsplit quant-report [--artifacts DIR] [--dataset synrgbd] [--seed N]
//!     per-stage QuantScheme report: derived role partitions, QDQ error and
//!     parameter count per granularity, and the full-vs-degraded plan
//!     latencies (see docs/QUANTIZATION.md)
//! pointsplit plan-search [--dataset synrgbd] [--variant pointsplit] [--fp32]
//!                     [--points N] [--batch K] [--devices cpu,gpu,edgetpu]
//!                     [--objective latency|throughput]
//!     placement search over the stage graph: enumerate device assignments
//!     (every Schedule over the available devices) under capability/memory
//!     constraints, report per-candidate PlanCost, mark the optimum
//! pointsplit verify   [--artifacts DIR] [--schedule gpu+edgetpu] [--batch 1]
//!                     [--boxes "gpu+edgetpu:2,gpu:1,cpu+edgetpu:1"] [--configs 2]
//!                     [--batch-max 4] [--sessions 64] [--session-cache-mb 64] [--verbose]
//!     static verification sweep: run the G/P/S/E rule set over every
//!     built-in configuration (all datasets x variants x precisions, plus
//!     seg-skip and SLO-degraded rewrites), the C rules over a cluster
//!     spec, and the S006 session-cache budget check; exit non-zero iff
//!     any Error fires (see docs/VERIFIER.md)
//! pointsplit devices
//!     print the calibrated device models
//! ```

use anyhow::{anyhow, Result};

use pointsplit::config::{parse_schedule, parse_variant, Cli};
use pointsplit::coordinator::{DetectorConfig, ScenePipeline, Schedule, Variant};
use pointsplit::data;
use pointsplit::runtime::{Manifest, Runtime};
use pointsplit::serving::{
    dispatch::PipelineExecutor, run_traffic, ArrivalPattern, BatchPolicy, LoadGen, ServicePlanner,
    SloPolicy, TrafficScenario,
};
use pointsplit::sim::{Device, DeviceKind};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let cli = Cli::parse(std::env::args().skip(1))?;
    match cli.command.as_str() {
        "check" => cmd_check(&cli),
        "detect" => cmd_detect(&cli),
        "serve" => cmd_serve(&cli),
        "serve-traffic" => cmd_serve_traffic(&cli),
        "serve-stream" => cmd_serve_stream(&cli),
        "serve-cluster" => cmd_serve_cluster(&cli),
        "quant-report" => cmd_quant_report(&cli),
        "plan-search" => cmd_plan_search(&cli),
        "verify" => cmd_verify(&cli),
        "devices" => cmd_devices(),
        "probe" => cmd_probe(&cli),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => Err(anyhow!(
            "unknown command '{other}' (try: check|detect|serve|serve-traffic|serve-stream|\
             serve-cluster|quant-report|plan-search|verify|devices)"
        )),
    }
}

fn print_help() {
    println!("pointsplit — on-device 3D detection with heterogeneous accelerators");
    println!(
        "commands: check | detect | serve | serve-traffic | serve-stream | serve-cluster | \
         quant-report | plan-search | verify | devices   (see rust/src/main.rs docs)"
    );
}

/// Open the artifacts runtime, falling back to the synthetic manifest +
/// deterministic host surrogate when no artifacts have been exported (so
/// `detect` / `serve` / `serve-traffic --functional` work out of the box).
fn open_runtime(cli: &Cli) -> Result<Runtime> {
    let dir = cli.get_or("artifacts", "artifacts");
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Runtime::open(dir)
    } else {
        eprintln!(
            "note: no artifacts at '{dir}' — using the synthetic manifest and the \
             deterministic host surrogate (run `make artifacts` for the real models)"
        );
        Ok(Runtime::synthetic())
    }
}

fn detector_config(cli: &Cli) -> Result<(DetectorConfig, &'static data::DatasetCfg)> {
    let dataset = cli.get_or("dataset", "synrgbd");
    let ds = data::dataset(&dataset).ok_or_else(|| anyhow!("unknown dataset '{dataset}'"))?;
    let variant = parse_variant(&cli.get_or("variant", "pointsplit"))?;
    let schedule = parse_schedule(&cli.get_or("schedule", "gpu+edgetpu"))?;
    let mut cfg = DetectorConfig::new(&dataset, variant, cli.get_bool("int8"), schedule);
    cfg.w0 = cli.get_f64("w0", cfg.w0 as f64)? as f32;
    cfg.bias_layers = cli.get_usize("bias-layers", cfg.bias_layers)?;
    if let Some(h) = cli.get("head-precision") {
        cfg.set_head_precision(h)?;
    }
    Ok((cfg, ds))
}

fn cmd_check(cli: &Cli) -> Result<()> {
    // `check` is explicitly about the exported artifacts: no fallback
    let rt = Runtime::open(cli.get_or("artifacts", "artifacts"))?;
    println!("platform: {}", rt.platform());
    let (ok, failures) = rt.check_all();
    println!("compiled {ok}/{} artifacts", rt.manifest.artifacts.len());
    for (n, e) in &failures {
        println!("  FAIL {n}: {e}");
    }
    if failures.is_empty() {
        Ok(())
    } else {
        Err(anyhow!("{} artifacts failed to compile", failures.len()))
    }
}

fn cmd_detect(cli: &Cli) -> Result<()> {
    let rt = open_runtime(cli)?;
    let (cfg, ds) = detector_config(cli)?;
    let seed = cli.get_usize("seed", 1)? as u64;
    let scene = data::generate_scene(seed, ds);
    println!(
        "scene seed={seed}: {} points, {} objects",
        scene.points.len(),
        scene.objects.len()
    );
    let pipe = ScenePipeline::new(&rt, cfg.clone());
    let out = pipe.run(&scene, seed)?;
    println!("\nvariant={} schedule={:?} int8={}", cfg.variant.name(), cfg.schedule, cfg.int8());
    println!("detections ({}):", out.detections.len());
    for d in out.detections.iter().take(12) {
        println!(
            "  {:<11} score {:.2}  c=({:+.2},{:+.2},{:.2}) s=({:.2},{:.2},{:.2}) yaw={:.2}",
            rt.manifest.classes[d.class],
            d.score,
            d.center[0],
            d.center[1],
            d.center[2],
            d.size[0],
            d.size[1],
            d.size[2],
            d.heading
        );
    }
    println!("\nground truth ({}):", scene.objects.len());
    for o in &scene.objects {
        println!(
            "  {:<11}            c=({:+.2},{:+.2},{:.2}) s=({:.2},{:.2},{:.2}) yaw={:.2}",
            rt.manifest.classes[o.class],
            o.center[0],
            o.center[1],
            o.center[2],
            o.size[0],
            o.size[1],
            o.size[2],
            o.heading
        );
    }
    println!("\nsimulated timeline ({:.1} ms total):", out.timeline.total_ms);
    for s in &out.timeline.stages {
        println!(
            "  {:>8.1} -> {:>8.1} ms  [{}] {}{}",
            s.start_ms,
            s.end_ms,
            s.device.name(),
            s.name,
            if s.comm_ms > 0.0 { format!("  (+{:.1} ms xfer)", s.comm_ms) } else { String::new() }
        );
    }
    for k in [DeviceKind::Gpu, DeviceKind::EdgeTpu, DeviceKind::Cpu] {
        if let Some(busy) = out.timeline.busy_ms.get(&k) {
            println!(
                "  {}: busy {:.1} ms, idle {:.1} ms",
                k.name(),
                busy,
                out.timeline.idle_ms(k)
            );
        }
    }
    println!("peak memory (modeled): {:.0} MB", out.peak_memory_mb);
    println!("host functional time: {:.1} ms", out.host_ms);
    if cli.get_bool("viz") {
        println!("\n{}", pointsplit::metrics::viz::bev_ascii(&scene, &out.detections, 0.35, 72));
        println!("{}", pointsplit::metrics::viz::gantt_ascii(&out.timeline, 72));
    }
    if let Some(path) = cli.get("trace") {
        std::fs::write(path, pointsplit::metrics::trace::to_chrome_trace(&out.timeline))?;
        println!("chrome trace written to {path} (open in chrome://tracing)");
    }
    Ok(())
}

fn cmd_serve(cli: &Cli) -> Result<()> {
    let rt = open_runtime(cli)?;
    let (cfg, ds) = detector_config(cli)?;
    let scenes = cli.get_usize("scenes", 32)?;
    let workers = cli.get_usize("workers", 4)?;
    let seed0 = cli.get_usize("seed", 100_000)? as u64;
    println!(
        "serving {scenes} {} scenes, variant={}, schedule={:?}, int8={}, workers={workers}",
        ds.name,
        cfg.variant.name(),
        cfg.schedule,
        cfg.int8()
    );
    let rep = pointsplit::coordinator::serve::serve(&rt, &cfg, ds, scenes, workers, seed0)?;
    println!("\nmAP@0.25 = {:.1}   mAP@0.5 = {:.1}", rep.map_25 * 100.0, rep.map_50 * 100.0);
    println!(
        "simulated latency: mean {:.0} ms  p50 {:.0}  p95 {:.0}",
        rep.sim_latency_ms.mean, rep.sim_latency_ms.p50, rep.sim_latency_ms.p95
    );
    println!(
        "host latency:      mean {:.0} ms  p50 {:.0}  p95 {:.0}  ({:.1} scenes/s wall)",
        rep.host_latency_ms.mean,
        rep.host_latency_ms.p50,
        rep.host_latency_ms.p95,
        rep.scenes as f64 / rep.wall_s
    );
    println!("peak memory (modeled): {:.0} MB", rep.peak_memory_mb);
    println!(
        "device busy: GPU {:.0} ms  NPU {:.0} ms  comm {:.0} ms (totals)",
        rep.busy_gpu_ms, rep.busy_npu_ms, rep.comm_ms
    );
    println!("\nper-class AP@0.25:");
    for (c, ap) in rt.manifest.classes.iter().zip(rep.per_class_ap25.iter()) {
        match ap {
            Some(v) => println!("  {:<11} {:.1}", c, v * 100.0),
            None => println!("  {:<11} -", c),
        }
    }
    Ok(())
}

/// Open-loop traffic gateway: generate arrivals against the simulated
/// clock, run them through admission/batching/SLO policies, and report
/// latency percentiles, drops, and goodput. Needs no artifacts — the
/// planner falls back to the synthetic manifest; pass `--functional` (with
/// artifacts and a real PJRT backend) to also execute scenes and report mAP.
fn cmd_serve_traffic(cli: &Cli) -> Result<()> {
    let (cfg, ds) = detector_config(cli)?;
    let manifest_path =
        std::path::Path::new(&cli.get_or("artifacts", "artifacts")).join("manifest.json");
    let planner = match std::fs::read_to_string(&manifest_path)
        .ok()
        .and_then(|t| Manifest::parse(&t).ok())
    {
        Some(m) => {
            println!("planner manifest: {}", manifest_path.display());
            ServicePlanner::new(m)
        }
        None => {
            println!("planner manifest: synthetic (no exported artifacts found)");
            ServicePlanner::synthetic()
        }
    };
    let batch = BatchPolicy {
        max_batch: cli.get_usize("batch-max", 4)?,
        max_wait_ms: cli.get_f64("batch-wait-ms", 25.0)?,
    };
    let capacity = planner.capacity_rps(&cfg, ds.num_points, batch.max_batch)?;
    let rate = if cli.get("rate").is_some() {
        cli.get_f64("rate", capacity)?
    } else {
        capacity * cli.get_f64("load", 0.8)?
    };
    let policy_name = cli.get_or("policy", "degrade");
    let policy = SloPolicy::parse(&policy_name)
        .ok_or_else(|| anyhow!("unknown policy '{policy_name}' (degrade|shed|none)"))?;
    let duration_ms = cli.get_f64("duration-s", 30.0)? * 1000.0;
    let deadline_ms = cli.get_f64("deadline-ms", 1000.0)?;
    let seed = cli.get_usize("seed", 1)? as u64;
    let pattern_arg = cli.get_or("pattern", "all");
    let poisson = ArrivalPattern::Poisson { rate_rps: rate };
    let bursty = ArrivalPattern::Bursty {
        base_rps: rate * 0.4,
        burst_rps: rate * 2.5,
        mean_burst_ms: 2_000.0,
        mean_calm_ms: 6_000.0,
    };
    let diurnal = ArrivalPattern::Diurnal {
        base_rps: rate * 0.4,
        peak_rps: rate * 1.6,
        period_s: duration_ms / 1000.0,
    };
    let patterns: Vec<ArrivalPattern> = match pattern_arg.as_str() {
        "poisson" => vec![poisson],
        "bursty" => vec![bursty],
        "diurnal" => vec![diurnal],
        "all" => vec![poisson, bursty, diurnal],
        other => return Err(anyhow!("unknown pattern '{other}' (poisson|bursty|diurnal|all)")),
    };
    println!(
        "serve-traffic: {} {} int8={} — capacity {:.1} rps at batch {}, target {:.1} rps, \
         deadline {:.0} ms, policy {}\n",
        ds.name,
        cfg.variant.name(),
        cfg.int8(),
        capacity,
        batch.max_batch,
        rate,
        deadline_ms,
        policy.name()
    );
    let rt_holder = if cli.get_bool("functional") { Some(open_runtime(cli)?) } else { None };
    // one long-lived per-scene worker pool shared across all patterns
    let exec = match (&rt_holder, cli.get("exec-workers")) {
        (Some(rt), Some(_)) => Some(PipelineExecutor::with_workers(
            rt,
            ds,
            cli.get_usize("exec-workers", 4)?,
        )),
        (Some(rt), None) => Some(PipelineExecutor::new(rt, ds)),
        (None, _) => None,
    };
    for pattern in patterns {
        let load = LoadGen {
            pattern,
            duration_ms,
            deadline_ms,
            hi_frac: cli.get_f64("hi-frac", 0.0)?,
            mix: vec![1.0],
            clients: cli.get_usize("clients", 0)?,
            seed,
        };
        let sc = TrafficScenario {
            name: format!("{}/{}/{}", ds.name, cfg.variant.name(), pattern.name()),
            configs: vec![cfg.clone()],
            num_points: ds.num_points,
            load,
            queue_capacity: cli.get_usize("queue-cap", 64)?,
            batch,
            policy,
        };
        let rep = run_traffic(&sc, &planner, exec.as_ref())?;
        rep.print();
        println!();
    }
    Ok(())
}

/// Temporal streaming demo: generate one frame sequence (seeded ego-motion,
/// per-object jitter, movers, periodic scene cuts), run every frame through
/// a single warm [`pointsplit::temporal::FrameCache`] session, and compare
/// against re-running the full single-scene pipeline cold on each frame.
/// Reports per-class frame counts, simulated per-frame latency (median), the
/// warm-over-cold speedup, and the cache footprint against its bound.
fn cmd_serve_stream(cli: &Cli) -> Result<()> {
    use pointsplit::data::stream::{generate_stream, StreamCfg};
    use pointsplit::temporal::{DeltaCfg, FrameCache};

    let rt = open_runtime(cli)?;
    let (cfg, ds) = detector_config(cli)?;
    let seed = cli.get_usize("seed", 1)? as u64;
    let scfg = StreamCfg {
        frames: cli.get_usize("frames", 32)?.max(1),
        cut_period: cli.get_usize("cut-period", StreamCfg::default().cut_period)?.max(1),
        ..StreamCfg::default()
    };
    let frames = generate_stream(seed, ds, scfg.clone());
    let pipe = ScenePipeline::new(&rt, cfg.clone());
    let bound = (cli.get_usize("session-cache-mb", 4)? as u64) << 20;
    let mut cache = FrameCache::new(DeltaCfg::default(), bound);
    println!(
        "serve-stream: {} {} int8={} — {} frames, cut every {}, session bound {} MB",
        ds.name,
        cfg.variant.name(),
        cfg.int8(),
        scfg.frames,
        scfg.cut_period,
        bound >> 20
    );
    let mut warm_ms: Vec<f64> = Vec::with_capacity(frames.len());
    let mut cold_ms: Vec<f64> = Vec::with_capacity(frames.len());
    for f in &frames {
        let (out, class) = pipe.run_stream(&f.scene, seed, &mut cache)?;
        let cold = pipe.run(&f.scene, seed)?;
        warm_ms.push(out.timeline.total_ms);
        cold_ms.push(cold.timeline.total_ms);
        println!(
            "  frame {:>3} shot {:>2}{}  {:<7}  warm {:>7.1} ms  cold {:>7.1} ms  {} dets",
            f.meta.index,
            f.meta.shot,
            if f.meta.is_cut { " CUT" } else { "    " },
            class.name(),
            out.timeline.total_ms,
            cold.timeline.total_ms,
            out.detections.len()
        );
    }
    let median = |xs: &[f64]| {
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.total_cmp(b));
        s[s.len() / 2]
    };
    let st = *cache.stats();
    let reuse_rate = (st.partial + st.reuse) as f64 / st.frames().max(1) as f64;
    let (wm, cm) = (median(&warm_ms), median(&cold_ms));
    println!(
        "\nframes: full {}  partial {}  reuse {}  (reuse rate {:.0}%)",
        st.full,
        st.partial,
        st.reuse,
        100.0 * reuse_rate
    );
    println!(
        "median simulated latency: warm {:.1} ms vs cold {:.1} ms  ({:.2}x)",
        wm,
        cm,
        cm / wm.max(1e-9)
    );
    println!(
        "session cache: {:.0} KB used of {} KB bound",
        cache.footprint_bytes() as f64 / 1024.0,
        cache.bound_bytes() >> 10
    );
    Ok(())
}

/// Fleet-scale gateway: parse a heterogeneous `ClusterSpec`, plan every
/// box via the placement search, and drive the whole fleet — router,
/// per-box engines, scripted faults, optional autoscaler — on one
/// simulated clock. Like `serve-traffic`, this needs no artifacts.
fn cmd_serve_cluster(cli: &Cli) -> Result<()> {
    use pointsplit::cluster::{
        self, inject, AutoscalePolicy, ClusterScenario, ClusterSpec, Fault, RouterPolicy,
    };

    let (cfg, ds) = detector_config(cli)?;
    let manifest_path =
        std::path::Path::new(&cli.get_or("artifacts", "artifacts")).join("manifest.json");
    let planner = match std::fs::read_to_string(&manifest_path)
        .ok()
        .and_then(|t| Manifest::parse(&t).ok())
    {
        Some(m) => {
            println!("planner manifest: {}", manifest_path.display());
            ServicePlanner::new(m)
        }
        None => {
            println!("planner manifest: synthetic (no exported artifacts found)");
            ServicePlanner::synthetic()
        }
    };
    let spec = ClusterSpec::parse(&cli.get_or("boxes", "gpu+edgetpu:2,gpu:1,cpu+edgetpu:1"))?;
    let configs = cluster::config_mix(&cfg, cli.get_usize("configs", 2)?);
    let mix = vec![1.0; configs.len()];
    let batch = BatchPolicy {
        max_batch: cli.get_usize("batch-max", 4)?,
        max_wait_ms: cli.get_f64("batch-wait-ms", 25.0)?,
    };
    let mut fleet_capacity = 0.0;
    for bt in &spec.boxes {
        fleet_capacity +=
            cluster::plan_box(&planner, bt, &configs, ds.num_points, &batch, &mix)?.capacity_rps;
    }
    let rate = if cli.get("rate").is_some() {
        cli.get_f64("rate", fleet_capacity)?
    } else {
        fleet_capacity * cli.get_f64("load", 0.8)?
    };
    let policy_name = cli.get_or("policy", "degrade");
    let policy = SloPolicy::parse(&policy_name)
        .ok_or_else(|| anyhow!("unknown policy '{policy_name}' (degrade|shed|none)"))?;
    let router_name = cli.get_or("router", "affinity");
    let router = RouterPolicy::parse(&router_name)
        .ok_or_else(|| anyhow!("unknown router '{router_name}' (affinity|random|least-loaded)"))?;
    let duration_ms = cli.get_f64("duration-s", 30.0)? * 1000.0;
    let deadline_ms = cli.get_f64("deadline-ms", 1000.0)?;
    let seed = cli.get_usize("seed", 1)? as u64;
    let pattern_arg = cli.get_or("pattern", "poisson");
    let pattern = match pattern_arg.as_str() {
        "poisson" => ArrivalPattern::Poisson { rate_rps: rate },
        "bursty" => ArrivalPattern::Bursty {
            base_rps: rate * 0.4,
            burst_rps: rate * 2.5,
            mean_burst_ms: 2_000.0,
            mean_calm_ms: 6_000.0,
        },
        "diurnal" => ArrivalPattern::Diurnal {
            base_rps: rate * 0.4,
            peak_rps: rate * 1.6,
            period_s: duration_ms / 1000.0,
        },
        other => return Err(anyhow!("unknown pattern '{other}' (poisson|bursty|diurnal)")),
    };
    let mut faults: Vec<Fault> = Vec::new();
    if let Some(s) = cli.get("kill") {
        faults.extend(inject::parse_kills(s)?);
    }
    if let Some(s) = cli.get("slow") {
        faults.extend(inject::parse_slows(s)?);
    }
    let autoscale = if cli.get_bool("autoscale") {
        Some(AutoscalePolicy {
            max_boxes: cli.get_usize("scale-max", 16)?,
            ..AutoscalePolicy::default()
        })
    } else {
        None
    };
    println!(
        "serve-cluster: {} boxes ({} types), {} config keys, fleet capacity {:.1} rps at \
         batch {}, target {:.1} rps, policy {}, router {}\n",
        spec.boxes.len(),
        spec.num_box_types(),
        configs.len(),
        fleet_capacity,
        batch.max_batch,
        rate,
        policy.name(),
        router.name()
    );
    let sc = ClusterScenario {
        name: format!("{}/{}boxes/{}", ds.name, spec.boxes.len(), pattern.name()),
        spec,
        configs,
        num_points: ds.num_points,
        queue_capacity: cli.get_usize("queue-cap", 32)?,
        load: LoadGen {
            pattern,
            duration_ms,
            deadline_ms,
            hi_frac: cli.get_f64("hi-frac", 0.0)?,
            mix,
            clients: cli.get_usize("clients", 0)?,
            seed,
        },
        batch,
        policy,
        router,
        router_seed: seed,
        faults,
        autoscale,
    };
    let trace = cluster::run_cluster(&sc, &planner)?;
    trace.report.print();
    if let Some(path) = cli.get("json") {
        std::fs::write(path, trace.report.to_json().to_string())?;
        println!("\nreport JSON written to {path}");
    }
    Ok(())
}

/// Placement search over the stage graph: every `Schedule` expressible on
/// the available devices, constrained by per-device capability and memory,
/// ranked by simulated cost. Recovers the paper's Pipelined GPU+EdgeTPU
/// assignment as optimal on the default calibration.
fn cmd_plan_search(cli: &Cli) -> Result<()> {
    use pointsplit::config::parse_device;
    use pointsplit::graph::place::{self, Objective};

    let dataset = cli.get_or("dataset", "synrgbd");
    let ds = data::dataset(&dataset).ok_or_else(|| anyhow!("unknown dataset '{dataset}'"))?;
    let variant = parse_variant(&cli.get_or("variant", "pointsplit"))?;
    let int8 = !cli.get_bool("fp32"); // the paper's search space is INT8 by default
    let cfg = DetectorConfig::new(
        &dataset,
        variant,
        int8,
        parse_schedule(&cli.get_or("schedule", "gpu+edgetpu"))?,
    );
    let num_points = cli.get_usize("points", ds.num_points)?;
    let batch = cli.get_usize("batch", 1)?;
    let objective = Objective::parse(&cli.get_or("objective", "latency"))
        .ok_or_else(|| anyhow!("unknown objective (latency|throughput)"))?;
    let devices: Vec<DeviceKind> = cli
        .get_or("devices", "cpu,gpu,edgetpu")
        .split(',')
        .map(parse_device)
        .collect::<Result<_>>()?;
    let manifest = {
        let path =
            std::path::Path::new(&cli.get_or("artifacts", "artifacts")).join("manifest.json");
        match std::fs::read_to_string(&path) {
            // a manifest that exists but cannot be read or parsed is a
            // hard error — never silently rank placements against the
            // wrong workloads; only a genuinely absent file falls back
            Ok(text) => {
                println!("manifest: {}", path.display());
                Manifest::parse(&text)?
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                println!("manifest: synthetic (no exported artifacts found)");
                Manifest::synthetic()
            }
            Err(e) => return Err(anyhow!("reading {}: {e}", path.display())),
        }
    };
    let search = place::search(&manifest, &cfg, num_points, batch, &devices, objective)?;
    println!(
        "plan-search: {dataset} {} int8={} — {} points, batch {batch}, objective {}, \
         devices {:?}",
        cfg.variant.name(),
        cfg.int8(),
        num_points,
        objective.name(),
        devices.iter().map(|d| d.name()).collect::<Vec<_>>()
    );
    let mut t = pointsplit::bench::Table::new(&[
        "placement",
        "total ms",
        "bottleneck ms",
        "GPU busy",
        "NPU busy",
        "CPU busy",
        "comm ms",
    ]);
    for (i, c) in search.candidates.iter().enumerate() {
        let mark = if i == 0 { " *" } else { "" };
        t.row(vec![
            format!("{:?}{mark}", c.schedule),
            format!("{:.0}", c.cost.total_ms),
            format!("{:.0}", c.cost.bottleneck_ms),
            format!("{:.0}", c.cost.busy_gpu_ms),
            format!("{:.0}", c.cost.busy_npu_ms),
            format!("{:.0}", c.cost.busy_cpu_ms),
            format!("{:.0}", c.cost.comm_ms),
        ]);
    }
    t.print("placement candidates (best first, * = optimal)");
    for r in &search.rejected {
        println!("  rejected {:?}: {}", r.schedule, r.reason);
    }
    if let Some(best) = search.best() {
        println!(
            "\noptimal placement: {:?}  ({:.0} ms latency, {:.0} ms bottleneck)",
            best.schedule, best.cost.total_ms, best.cost.bottleneck_ms
        );
    }
    Ok(())
}

/// Per-stage quantization report: for each head network, run the fp32
/// reference at a probe input, derive the role partition from its output
/// channels, and compare QDQ error + parameter count across granularities;
/// then show how the SLO degrade path re-assigns stage precisions and what
/// the calibrated device model says each scheme costs.
fn cmd_quant_report(cli: &Cli) -> Result<()> {
    use pointsplit::quant::{self, derive_roles, Granularity, StagePrecision};
    use pointsplit::util::tensor::Tensor;

    let rt = open_runtime(cli)?;
    let m = &rt.manifest;
    let dataset = cli.get_or("dataset", "synrgbd");
    if !m.datasets.contains_key(&dataset) {
        return Err(anyhow!("unknown dataset '{dataset}'"));
    }
    let seed = cli.get_usize("seed", 1)? as u64;

    for net in ["vote", "prop"] {
        let art = format!("{dataset}_pointsplit_{net}_fp32");
        let meta = rt
            .manifest
            .artifact(&art)
            .ok_or_else(|| anyhow!("artifact '{art}' missing"))?
            .clone();
        // deterministic probe activations through the fp32 reference
        let shape = meta.input_shapes[0].clone();
        let n: usize = shape.iter().product();
        let x = Tensor::new(
            shape,
            (0..n)
                .map(|i| (0.1 + 0.001 * (i as u64 + seed) as f64).sin() as f32)
                .collect(),
        );
        let out = rt.run(&art, &[&x])?.remove(0);
        let (lo, hi) = quant::channel_minmax(&out);
        let derived = derive_roles(&lo, &hi, 4);
        let (cout, declared) = m.stage_channels(net);
        println!(
            "\n{net}: {cout} output channels — declared roles {:?}, derived {:?} (sizes)",
            declared.iter().map(|g| g.len()).collect::<Vec<_>>(),
            derived.iter().map(|g| g.len()).collect::<Vec<_>>()
        );
        let mut t = pointsplit::bench::Table::new(&[
            "granularity",
            "groups",
            "# params",
            "qdq mse",
        ]);
        for g in [
            Granularity::Layer,
            Granularity::Group(declared.len().max(2)),
            Granularity::Channel,
            Granularity::Role,
        ] {
            let spec = m.stage_quant_for(&meta, StagePrecision::Int8(g));
            let act = spec.calibrate(&out);
            let mse = quant::qdq_mse(&out, &act)?;
            t.row(vec![
                StagePrecision::Int8(g).head_name().to_string(),
                act.num_groups.to_string(),
                act.param_count().to_string(),
                format!("{mse:.2e}"),
            ]);
        }
        t.print(&format!("{dataset} {net} head — QDQ error per granularity"));
    }

    // the SLO degrade move, priced by the calibrated device model
    let sched = Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu };
    let full = DetectorConfig::new(&dataset, Variant::PointSplit, true, sched);
    let fast = pointsplit::serving::slo::degraded_config(&full);
    let fp32 = DetectorConfig::new(&dataset, Variant::PointSplit, false, sched);
    let planner = ServicePlanner::new(rt.manifest.clone());
    let num_points = m.datasets[&dataset].num_points;
    let fast_points = pointsplit::serving::slo::degraded_points(num_points);
    let mut t = pointsplit::bench::Table::new(&[
        "scheme",
        "stage precisions",
        "latency ms",
        "capacity rps",
    ]);
    for (name, cfg, pts, skip_seg) in [
        ("fp32", &fp32, num_points, false),
        ("int8 role (full)", &full, num_points, false),
        ("degraded fast path", &fast, fast_points, true),
    ] {
        let cost = planner.cost(cfg, pts, 1, skip_seg)?;
        t.row(vec![
            name.to_string(),
            cfg.scheme.key(),
            format!("{:.0}", cost.total_ms),
            format!("{:.1}", planner.capacity_rps(cfg, pts, 4)?),
        ]);
    }
    // the quant-rewrite pass in isolation — same point budget, same 2D
    // work, only the stage specs swapped — decomposes the fast path's win
    // into the precision move vs the point-budget/seg-reuse moves
    let full_graph = planner.graph(&full, num_points, false)?;
    let rewrite = pointsplit::serving::slo::degraded_graph(planner.manifest(), &full_graph)?;
    let rw1 = planner.cost_of_graph(&rewrite, 1);
    t.row(vec![
        "degraded (quant-rewrite only)".to_string(),
        rewrite.cfg().scheme.key(),
        format!("{:.0}", rw1.total_ms),
        format!("{:.1}", planner.capacity_rps_of_graph(&rewrite, 4)),
    ]);
    t.print(&format!(
        "{dataset} — how SLO degrade re-assigns stage precisions (batch-1 latency, batch-4 capacity)"
    ));
    Ok(())
}

/// Execute one artifact at the deterministic probe input and print output
/// stats (debugging aid for JAX<->Rust parity).
fn cmd_probe(cli: &Cli) -> Result<()> {
    let rt = open_runtime(cli)?;
    let name = cli.positional.first().ok_or_else(|| anyhow!("usage: probe <artifact>"))?;
    let meta = rt.manifest.artifact(name).ok_or_else(|| anyhow!("unknown artifact"))?;
    let inputs: Vec<pointsplit::util::tensor::Tensor> = meta
        .input_shapes
        .iter()
        .map(|shape| {
            let n: usize = shape.iter().product();
            pointsplit::util::tensor::Tensor::new(
                shape.clone(),
                (0..n).map(|i| (0.1 + 0.001 * i as f64).sin() as f32).collect(),
            )
        })
        .collect();
    let refs: Vec<&pointsplit::util::tensor::Tensor> = inputs.iter().collect();
    let outs = rt.run(name, &refs)?;
    for (i, o) in outs.iter().enumerate() {
        let mean = o.data.iter().map(|&x| x as f64).sum::<f64>() / o.data.len() as f64;
        let std = (o.data.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>()
            / o.data.len() as f64)
            .sqrt();
        println!("out[{i}] shape {:?} mean {mean:.6} std {std:.6} first {:?}", o.shape, &o.data[..6.min(o.data.len())]);
    }
    Ok(())
}

/// Static verification sweep (the CI gate): run the full G/P/S/E rule set
/// over every built-in configuration — all manifest datasets × variants ×
/// precisions, each as base graph, seg-skip rewrite (painted variants) and
/// SLO-degraded quant-rewrite — then the C rules over a cluster spec, the
/// same way `serve-cluster` would provision it. Errors are always printed
/// and make the command exit non-zero; warnings are advisory (printed
/// under `--verbose`, counted otherwise).
fn cmd_verify(cli: &Cli) -> Result<()> {
    use pointsplit::cluster::{self, ClusterSpec};
    use pointsplit::verify;

    let manifest = {
        let path =
            std::path::Path::new(&cli.get_or("artifacts", "artifacts")).join("manifest.json");
        match std::fs::read_to_string(&path) {
            // same policy as plan-search: a present-but-broken manifest is
            // a hard error; only a genuinely absent file falls back
            Ok(text) => {
                println!("manifest: {}", path.display());
                Manifest::parse(&text)?
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                println!("manifest: synthetic (no exported artifacts found)");
                Manifest::synthetic()
            }
            Err(e) => return Err(anyhow!("reading {}: {e}", path.display())),
        }
    };
    let planner = ServicePlanner::new(manifest);
    let schedule = parse_schedule(&cli.get_or("schedule", "gpu+edgetpu"))?;
    let batch = cli.get_usize("batch", 1)?;
    let verbose = cli.get_bool("verbose");

    let mut datasets: Vec<String> = planner.manifest().datasets.keys().cloned().collect();
    datasets.sort();
    let (mut graphs, mut errors, mut warnings) = (0usize, 0usize, 0usize);
    let mut table = pointsplit::bench::Table::new(&["config", "graphs", "errors", "warnings"]);
    for dataset in &datasets {
        let num_points = planner.manifest().datasets[dataset].num_points;
        for variant in
            [Variant::VoteNet, Variant::PointPainting, Variant::RandomSplit, Variant::PointSplit]
        {
            for int8 in [false, true] {
                let cfg = DetectorConfig::new(dataset, variant, int8, schedule);
                let label = format!(
                    "{dataset}/{}/{}",
                    cfg.variant.name(),
                    if int8 { "int8" } else { "fp32" }
                );
                let mut reports: Vec<(&str, verify::Report)> = Vec::new();
                let base = planner.graph(&cfg, num_points, false)?;
                reports.push((
                    "base",
                    verify::verify_all(planner.sim(), planner.manifest(), &base, batch),
                ));
                if cfg.variant.painted() {
                    let skip = planner.graph(&cfg, num_points, true)?;
                    reports.push((
                        "seg-skip",
                        verify::verify_all(planner.sim(), planner.manifest(), &skip, batch),
                    ));
                }
                let fast = pointsplit::serving::slo::degraded_graph(planner.manifest(), &base)?;
                reports.push((
                    "degraded",
                    verify::verify_all(planner.sim(), planner.manifest(), &fast, batch),
                ));
                let (mut ne, mut nw) = (0usize, 0usize);
                for (tag, rep) in &reports {
                    ne += rep.errors().len();
                    nw += rep.warnings().len();
                    for d in &rep.diagnostics {
                        if d.severity == verify::Severity::Error || verbose {
                            println!("  {label} [{tag}] {d}");
                        }
                    }
                }
                graphs += reports.len();
                errors += ne;
                warnings += nw;
                table.row(vec![label, reports.len().to_string(), ne.to_string(), nw.to_string()]);
            }
        }
    }
    table.print("per-config verification (base + seg-skip + degraded graphs)");

    // the fleet plan, verified exactly the way serve-cluster provisions it
    let spec = ClusterSpec::parse(&cli.get_or("boxes", "gpu+edgetpu:2,gpu:1,cpu+edgetpu:1"))?;
    let ds0 = datasets.first().ok_or_else(|| anyhow!("manifest declares no datasets"))?;
    let base_cfg = DetectorConfig::new(ds0, Variant::PointSplit, true, schedule);
    let configs = cluster::config_mix(&base_cfg, cli.get_usize("configs", 2)?);
    let mix = vec![1.0; configs.len()];
    let bp = BatchPolicy {
        max_batch: cli.get_usize("batch-max", 4)?,
        max_wait_ms: cli.get_f64("batch-wait-ms", 25.0)?,
    };
    let num_points = planner.manifest().datasets[ds0].num_points;
    let crep = verify::verify_cluster(&planner, &spec, &configs, num_points, &bp, &mix);
    for d in &crep.diagnostics {
        if d.severity == verify::Severity::Error || verbose {
            println!("  cluster {d}");
        }
    }
    println!(
        "cluster: {} box types x {} config keys at batch {} — {} error(s), {} warning(s)",
        spec.num_box_types(),
        configs.len(),
        bp.max_batch,
        crep.errors().len(),
        crep.warnings().len()
    );
    errors += crep.errors().len();
    warnings += crep.warnings().len();

    // the streaming session cache, sized the way the gateway provisions it:
    // per-session declared bytes from the canonical footprint formula x the
    // session-map capacity, against the configured memory bound (S006)
    let sessions = cli.get_usize("sessions", 64)?;
    let cache_bound = (cli.get_usize("session-cache-mb", 64)? as u64) << 20;
    let m0 = planner.manifest();
    let per_session = pointsplit::temporal::session_footprint_bytes(
        num_points,
        m0.num_seeds,
        m0.seed_feat,
        m0.classes.len() + 1,
        m0.img_size,
    );
    let srep = verify::verify_session_cache(sessions, per_session, cache_bound);
    for d in &srep.diagnostics {
        if d.severity == verify::Severity::Error || verbose {
            println!("  session-cache {d}");
        }
    }
    println!(
        "session cache: {sessions} sessions x {:.0} KB declared vs {} MB bound — {} error(s)",
        per_session as f64 / 1024.0,
        cache_bound >> 20,
        srep.errors().len()
    );
    errors += srep.errors().len();
    warnings += srep.warnings().len();

    println!(
        "\nverified {graphs} graphs + 1 cluster spec + 1 session-cache budget: \
         {errors} error(s), {warnings} warning(s)"
    );
    if errors > 0 {
        return Err(anyhow!("verification failed with {errors} error(s)"));
    }
    println!("all checks passed");
    Ok(())
}

fn cmd_devices() -> Result<()> {
    for d in [Device::cpu(), Device::gpu(), Device::edgetpu()] {
        println!("{:?}", d);
    }
    println!("\nschedules: gpu | gpu>edgetpu (sequential) | gpu+edgetpu (pipelined)");
    let _ = Schedule::SingleDevice(DeviceKind::Gpu);
    let _ = Variant::PointSplit;
    Ok(())
}
