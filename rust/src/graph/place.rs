//! Placement-search pass: which device should each stage class sit on?
//!
//! The paper hardcodes its headline assignment — point manipulation on the
//! GPU, quantized NNs on the EdgeTPU, two overlapped pipelines (Fig. 3) —
//! and evaluates three alternatives by hand (Fig. 10's processor pairings).
//! This pass turns that table into a search: enumerate every
//! [`Schedule`] expressible over the available devices, build the **same**
//! [`StageGraph`] for each, rule out assignments that violate a device's
//! capability (the EdgeTPU runs int8 NNs only, never point ops) or memory
//! capacity (a stage's working set must fit, see
//! [`crate::sim::Device::fits`]), and rank the survivors by simulated cost.
//!
//! The existing `Schedule::{SingleDevice, Sequential, Pipelined}` variants
//! are exactly the *named placement policies* of this search space; the
//! search recovers the paper's `Pipelined { GPU, EdgeTPU }` as optimal on
//! the default calibration (pinned by `search_recovers_paper_assignment`).
//!
//! Consumers: the `plan-search` CLI command and `benches/fig10_hw_configs`.

use anyhow::{anyhow, Result};

use super::StageGraph;
use crate::coordinator::{DetectorConfig, Schedule};
use crate::runtime::Manifest;
use crate::sim::{cost_of, DeviceKind, PlanCost, ScheduleSim, StageSpec};

/// What the search minimizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Critical-path latency of one batch (`total_ms`), ties broken by
    /// `bottleneck_ms`.
    Latency,
    /// Steady-state admission period (`bottleneck_ms` — the busiest
    /// device's occupancy sets the service rate), ties broken by
    /// `total_ms`.
    Throughput,
}

impl Objective {
    pub fn parse(s: &str) -> Option<Objective> {
        match s.to_ascii_lowercase().as_str() {
            "latency" | "lat" | "total" => Some(Objective::Latency),
            "throughput" | "rps" | "capacity" | "bottleneck" => Some(Objective::Throughput),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Objective::Latency => "latency",
            Objective::Throughput => "throughput",
        }
    }

    fn key(&self, c: &PlanCost) -> (f64, f64) {
        match self {
            Objective::Latency => (c.total_ms, c.bottleneck_ms),
            Objective::Throughput => (c.bottleneck_ms, c.total_ms),
        }
    }
}

/// One feasible assignment with its simulated cost.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub schedule: Schedule,
    pub cost: PlanCost,
}

/// One assignment ruled out before simulation.
#[derive(Debug, Clone)]
pub struct Rejected {
    pub schedule: Schedule,
    pub reason: String,
}

/// Search result: feasible candidates best-first, plus the assignments the
/// constraints eliminated (reported, not silently dropped).
#[derive(Debug)]
pub struct PlacementSearch {
    pub objective: Objective,
    pub candidates: Vec<Candidate>,
    pub rejected: Vec<Rejected>,
}

impl PlacementSearch {
    pub fn best(&self) -> Option<&Candidate> {
        self.candidates.first()
    }
}

/// Every schedule expressible over the available devices: each device
/// solo, plus every (point_dev, nn_dev) pairing sequential and pipelined.
/// `Pipelined { d, d }` is kept — it is a real pairing (the paper's CPU-CPU
/// column overlaps the CPU's point-op and NN thread pools for a 1.7x gain)
/// — while `Sequential { d, d }` is dropped as an alias of
/// `SingleDevice(d)`.
pub fn enumerate_schedules(avail: &[DeviceKind]) -> Vec<Schedule> {
    let mut out = Vec::new();
    for &d in avail {
        out.push(Schedule::SingleDevice(d));
    }
    for &pd in avail {
        for &nd in avail {
            if pd != nd {
                out.push(Schedule::Sequential { point_dev: pd, nn_dev: nd });
            }
            out.push(Schedule::Pipelined { point_dev: pd, nn_dev: nd });
        }
    }
    out
}

/// Run the search against the default calibrated device models.
pub fn search(
    m: &Manifest,
    cfg: &DetectorConfig,
    num_points: usize,
    batch: usize,
    avail: &[DeviceKind],
    objective: Objective,
) -> Result<PlacementSearch> {
    search_with_sim(&ScheduleSim::new(), m, cfg, num_points, batch, avail, objective)
}

/// Run the search against explicit device models (what-if analyses and
/// constraint tests inject modified devices here).
pub fn search_with_sim(
    sim: &ScheduleSim,
    m: &Manifest,
    cfg: &DetectorConfig,
    num_points: usize,
    batch: usize,
    avail: &[DeviceKind],
    objective: Objective,
) -> Result<PlacementSearch> {
    let mut candidates: Vec<Candidate> = Vec::new();
    let mut rejected: Vec<Rejected> = Vec::new();
    for schedule in enumerate_schedules(avail) {
        let mut c = cfg.clone();
        c.schedule = schedule;
        let graph = StageGraph::build(m, &c, num_points, false)?;
        let folded = graph.batch_fold(batch);
        // A schedule whose declared NN device ends up running *nothing*
        // (every NN stage fell back off the EdgeTPU — e.g. an fp32 scheme)
        // is a degenerate alias of a cheaper assignment, not a real
        // candidate; report it instead of ranking a misleading label.
        let nn_dev = schedule.nn_dev();
        if nn_dev != schedule.point_dev() && !folded.iter().any(|s| s.device == nn_dev) {
            rejected.push(Rejected {
                schedule,
                reason: format!(
                    "degenerate: no stage of this scheme can execute on {} \
                     (fp32 NN falls back to {})",
                    nn_dev.name(),
                    schedule.point_dev().name()
                ),
            });
            continue;
        }
        match check_constraints(sim, &folded) {
            Err(reason) => rejected.push(Rejected { schedule, reason }),
            Ok(()) => {
                let cost = cost_of(&sim.run(&folded));
                candidates.push(Candidate { schedule, cost });
            }
        }
    }
    candidates.sort_by(|a, b| {
        objective
            .key(&a.cost)
            .partial_cmp(&objective.key(&b.cost))
            .expect("simulated costs are finite")
    });
    Ok(PlacementSearch { objective, candidates, rejected })
}

/// The winning schedule for a config on a box with exactly `avail` devices
/// — the cluster planner's entry point: every box type gets its plan from
/// the same search the `plan-search` command exposes. Errors when no
/// assignment is feasible (e.g. an EdgeTPU-only box, which cannot run
/// point ops at all).
pub fn best_schedule(
    m: &Manifest,
    cfg: &DetectorConfig,
    num_points: usize,
    batch: usize,
    avail: &[DeviceKind],
    objective: Objective,
) -> Result<Schedule> {
    let s = search(m, cfg, num_points, batch, avail, objective)?;
    s.best().map(|c| c.schedule).ok_or_else(|| {
        anyhow!(
            "no feasible placement for {} on [{}]: {}",
            cfg.variant.name(),
            avail.iter().map(|d| d.name()).collect::<Vec<_>>().join("+"),
            s.rejected
                .first()
                .map(|r| r.reason.clone())
                .unwrap_or_else(|| "no devices".to_string())
        )
    })
}

/// Capability + memory constraints, checked per stage at the folded batch
/// size (a batch that overflows a device's capacity is rejected even when
/// a single scene would fit). Delegates to the verifier's shared P001/S001
/// rule so search rejections and `verify` diagnostics can never disagree.
fn check_constraints(sim: &ScheduleSim, folded: &[StageSpec]) -> std::result::Result<(), String> {
    let rep = crate::verify::check_specs(sim, folded);
    match rep.errors().first() {
        Some(d) => Err(d.message.clone()),
        None => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Variant;
    use crate::sim::Device;

    const ALL: [DeviceKind; 3] = [DeviceKind::Cpu, DeviceKind::Gpu, DeviceKind::EdgeTpu];

    fn split_cfg() -> DetectorConfig {
        DetectorConfig::new(
            "synrgbd",
            Variant::PointSplit,
            true,
            Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
        )
    }

    /// Acceptance: on the default calibration with both GPU and EdgeTPU
    /// available, the search recovers the paper's Pipelined GPU+NPU
    /// assignment as optimal — under both objectives.
    #[test]
    fn search_recovers_paper_assignment() {
        let m = Manifest::synthetic();
        for objective in [Objective::Latency, Objective::Throughput] {
            let s = search(&m, &split_cfg(), 2048, 1, &ALL, objective).expect("search");
            let best = s.best().expect("feasible candidates");
            assert_eq!(
                best.schedule,
                Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
                "{objective:?}: expected the paper's GPU+EdgeTPU pipeline, got {:?}\n{:#?}",
                best.schedule,
                s.candidates
            );
        }
    }

    #[test]
    fn capability_constraints_reject_pointops_on_the_edgetpu() {
        let m = Manifest::synthetic();
        let s = search(&m, &split_cfg(), 2048, 1, &ALL, Objective::Latency).unwrap();
        assert!(
            s.rejected
                .iter()
                .any(|r| r.schedule == Schedule::SingleDevice(DeviceKind::EdgeTpu)
                    && r.reason.contains("unsupported")),
            "EdgeTPU-only must be rejected: {:?}",
            s.rejected
        );
        for c in &s.candidates {
            assert_ne!(c.schedule.point_dev(), DeviceKind::EdgeTpu);
        }
    }

    /// An fp32 scheme cannot use the EdgeTPU at all: every EdgeTPU-NN
    /// pairing must land in `rejected` as degenerate (not be ranked under
    /// a misleading label), and the winner must be a pairing whose NN
    /// device actually executes work.
    #[test]
    fn fp32_rejects_edgetpu_pairings_as_degenerate() {
        let m = Manifest::synthetic();
        let mut cfg = split_cfg();
        cfg.scheme = crate::quant::QuantScheme::fp32();
        let s = search(&m, &cfg, 2048, 1, &ALL, Objective::Latency).unwrap();
        for c in &s.candidates {
            assert!(
                c.schedule.nn_dev() != DeviceKind::EdgeTpu
                    || c.schedule.point_dev() == c.schedule.nn_dev(),
                "degenerate EdgeTPU pairing ranked as a candidate: {:?}",
                c.schedule
            );
        }
        assert!(
            s.rejected.iter().any(|r| r.reason.contains("degenerate")),
            "EdgeTPU pairings must be reported as degenerate: {:?}",
            s.rejected
        );
        // with the NPU out of reach, the best fp32 placement overlaps the
        // GPU point lane with the (slow but real) CPU NN lane
        assert_eq!(
            s.best().unwrap().schedule,
            Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::Cpu },
        );
    }

    #[test]
    fn memory_constraint_rejects_overflowing_assignments() {
        let m = Manifest::synthetic();
        // shrink the EdgeTPU's SRAM below any NN stage's working set
        let mut tiny = Device::edgetpu();
        tiny.mem_capacity_bytes = 16;
        let sim = ScheduleSim::new().with_device(tiny);
        let s = search_with_sim(&sim, &m, &split_cfg(), 2048, 1, &ALL, Objective::Latency)
            .expect("search");
        assert!(
            !s.candidates.iter().any(|c| c.schedule.nn_dev() == DeviceKind::EdgeTpu
                && c.schedule.point_dev() != c.schedule.nn_dev()),
            "no EdgeTPU NN assignment may survive a 16-byte capacity"
        );
        assert!(s.rejected.iter().any(|r| r.reason.contains("capacity")));
        // the search still finds a feasible fallback
        assert!(s.best().is_some());
    }

    #[test]
    fn throughput_and_latency_objectives_rank_consistently() {
        let m = Manifest::synthetic();
        let lat = search(&m, &split_cfg(), 2048, 4, &ALL, Objective::Latency).unwrap();
        let thr = search(&m, &split_cfg(), 2048, 4, &ALL, Objective::Throughput).unwrap();
        assert_eq!(lat.candidates.len(), thr.candidates.len());
        for w in lat.candidates.windows(2) {
            assert!(w[0].cost.total_ms <= w[1].cost.total_ms + 1e-9);
        }
        for w in thr.candidates.windows(2) {
            assert!(w[0].cost.bottleneck_ms <= w[1].cost.bottleneck_ms + 1e-9);
        }
    }
}
