//! Paper Table 3 analog: implementation parity.
//!
//! The paper validates its from-scratch TensorFlow VoteNet against the
//! original PyTorch release (per-class mAP within ~1 point). Our analog:
//! the Rust+PJRT execution of every exported artifact must match the JAX
//! reference *numerically* at deterministic probe inputs
//! (artifacts/fixtures.json, written at export time), and the end-to-end
//! Rust pipeline must reproduce the JAX pipeline's detections.

mod common;

use pointsplit::bench::Table;
use pointsplit::util::json::Json;
use pointsplit::util::tensor::Tensor;

/// Probe input mirrored from python/compile/aot.py: x[i] = sin(0.1 + 0.001 i).
fn probe(shape: &[usize]) -> Tensor {
    let n: usize = shape.iter().product();
    let data = (0..n).map(|i| (0.1 + 0.001 * i as f64).sin() as f32).collect();
    Tensor::new(shape.to_vec(), data)
}

fn main() {
    let rt = common::open_runtime();
    let text = std::fs::read_to_string("artifacts/fixtures.json")
        .expect("fixtures.json missing — re-run `make artifacts`");
    let fixtures = Json::parse(&text).unwrap();
    let mut t = Table::new(&["artifact", "jax mean", "rust mean", "max |dfirst|", "status"]);
    let mut worst = 0.0f64;
    for (name, fx) in fixtures.as_obj().unwrap() {
        let meta = rt.manifest.artifact(name).expect("fixture artifact in manifest");
        let inputs: Vec<Tensor> = meta.input_shapes.iter().map(|s| probe(s)).collect();
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let out = rt.run(name, &refs).expect("execute")[0].clone();
        let mean = out.data.iter().map(|&x| x as f64).sum::<f64>() / out.data.len() as f64;
        let jax_mean = fx.req("mean").as_f64().unwrap();
        let first = fx.req("first").f64_vec();
        let d_first = first
            .iter()
            .zip(out.data.iter())
            .map(|(a, &b)| (a - b as f64).abs())
            .fold(0.0f64, f64::max);
        let scale = fx.req("l1").as_f64().unwrap().max(1e-3);
        let ok = d_first / scale < 1e-3 && (mean - jax_mean).abs() / scale < 1e-3;
        worst = worst.max(d_first / scale);
        t.row(vec![
            name.clone(),
            format!("{jax_mean:.5}"),
            format!("{mean:.5}"),
            format!("{d_first:.2e}"),
            if ok { "MATCH".into() } else { "MISMATCH".into() },
        ]);
    }
    t.print("Table 3 analog — JAX reference vs Rust/PJRT execution parity");
    println!("\nworst relative first-element deviation: {worst:.2e}");
    println!("(paper Table 3: TF reimplementation within 0.8 overall mAP of PyTorch VoteNet)");
    assert!(worst < 1e-3, "parity violated");
}
