//! Substrate utilities built from scratch (no crates vendored for these):
//! JSON codec, deterministic PRNG, dense tensors, CLI args, property tests.

pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod tensor;
