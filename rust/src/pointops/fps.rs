//! Farthest point sampling — regular and 2D-semantics-biased (paper Eq. 1).
//!
//! Mirrors python/compile/sampling.py `fps` exactly: configurable deterministic start (default
//! index 0), incremental min-distance update, first-max tie-breaking. The
//! biased variant scales each pairwise distance by `w0` when *either*
//! endpoint is foreground, so foreground points look "farther" and are
//! selected more often (w0 > 1) or less often (w0 < 1).
//!
//! §Perf: the production scan runs over [`PointsSoA`] in fixed-width
//! `[f32; LANES]` chunks (`scan_chunk_lanes`) — three contiguous coordinate
//! streams auto-vectorize where the interleaved layout gathered. Each lane
//! keeps its own running first-max and the lanes are combined by
//! (max value, then smallest index), which equals the scalar left-to-right
//! strict-`>` scan; the scalar tail then continues the same reduction, so
//! the SIMD result is **bit-identical** to [`fps_scalar`] (the original
//! code, kept as the oracle). The rolling `min_d2` buffer comes from the
//! per-worker `ScratchArena`, so steady-state calls allocate only the
//! output indices.
//!
//! The `_par` entry points additionally run the per-iteration scan chunked
//! over scoped threads. Each thread owns a contiguous slice of `min_d2` and
//! reports its chunk's first-max; the reduction combines chunks in index
//! order with a strict `>`, so the result is bit-identical to the
//! sequential scan for any thread count (the determinism contract of
//! `exec::DagExecutor`). Small clouds fall back to the sequential path —
//! the scan is memory-bound and thread handoff only pays off past a few
//! thousand points. Thread budgets are clamped to the point count and
//! `threads == 0` behaves as 1.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Barrier, Mutex};

use super::arena::{with_arena, ScratchArena};
use super::soa::{PointsSoA, LANES};

/// Below this cloud size the parallel scan is not worth the barriers.
const PAR_MIN_POINTS: usize = 4096;
/// Minimum chunk a scan thread is worth spawning for.
const PAR_MIN_CHUNK: usize = 1024;

/// Regular FPS: returns `m` indices into `xyz`.
pub fn fps(xyz: &[[f32; 3]], m: usize) -> Vec<usize> {
    fps_impl(xyz, m, None, 1.0, 0, 1)
}

/// Regular FPS with an inner-loop thread budget.
pub fn fps_par(xyz: &[[f32; 3]], m: usize, threads: usize) -> Vec<usize> {
    fps_impl(xyz, m, None, 1.0, 0, threads)
}

/// FPS from an explicit start index (the SA-bias pipeline starts at n/2 so
/// the two pipeline views stay decorrelated; mirrors sampling.fps(start=)).
pub fn fps_from(xyz: &[[f32; 3]], m: usize, start: usize) -> Vec<usize> {
    fps_impl(xyz, m, None, 1.0, start, 1)
}

/// `fps_from` with an inner-loop thread budget.
pub fn fps_from_par(xyz: &[[f32; 3]], m: usize, start: usize, threads: usize) -> Vec<usize> {
    fps_impl(xyz, m, None, 1.0, start, threads)
}

/// Biased FPS (paper Eq. 1): `fg[i]` in {0,1}; `w0` weights pairs touching
/// the foreground set A.
pub fn biased_fps(xyz: &[[f32; 3]], m: usize, fg: &[f32], w0: f32) -> Vec<usize> {
    fps_impl(xyz, m, Some(fg), w0, 0, 1)
}

/// `biased_fps` with an inner-loop thread budget.
pub fn biased_fps_par(
    xyz: &[[f32; 3]],
    m: usize,
    fg: &[f32],
    w0: f32,
    threads: usize,
) -> Vec<usize> {
    fps_impl(xyz, m, Some(fg), w0, 0, threads)
}

/// Biased FPS from an explicit start index.
pub fn biased_fps_from(
    xyz: &[[f32; 3]],
    m: usize,
    fg: &[f32],
    w0: f32,
    start: usize,
) -> Vec<usize> {
    fps_impl(xyz, m, Some(fg), w0, start, 1)
}

/// `biased_fps_from` with an inner-loop thread budget.
pub fn biased_fps_from_par(
    xyz: &[[f32; 3]],
    m: usize,
    fg: &[f32],
    w0: f32,
    start: usize,
    threads: usize,
) -> Vec<usize> {
    fps_impl(xyz, m, Some(fg), w0, start, threads)
}

/// FPS over a cloud already in SoA layout (the pipeline's steady path —
/// skips the conversion copy).
pub fn fps_soa(pts: &PointsSoA, m: usize, start: usize, threads: usize) -> Vec<usize> {
    fps_soa_impl(pts, m, None, 1.0, start, threads)
}

/// Biased FPS over a cloud already in SoA layout.
pub fn biased_fps_soa(
    pts: &PointsSoA,
    m: usize,
    fg: &[f32],
    w0: f32,
    start: usize,
    threads: usize,
) -> Vec<usize> {
    fps_soa_impl(pts, m, Some(fg), w0, start, threads)
}

fn check_args(n: usize, m: usize, start: usize, fg: Option<&[f32]>) {
    assert!(m >= 1 && m <= n, "fps: m={m} out of range for n={n}");
    // reject — don't silently clamp — a start index outside the cloud
    assert!(start < n, "fps: start={start} out of range for n={n}");
    if let Some(f) = fg {
        assert_eq!(f.len(), n);
    }
}

/// Hoist the per-pair bias branch by specializing the unbiased path (the
/// common case: every SA layer of SA-normal plus SA3+ of SA-bias).
fn bias_of<'f>(fg: Option<&'f [f32]>, w0: f32) -> Option<(&'f [f32], f32)> {
    match fg {
        Some(f) if w0 != 1.0 => Some((f, w0)),
        _ => None,
    }
}

/// Effective inner-loop thread count: the raw budget is clamped to the
/// point count (`threads == 0` behaves as 1), then small clouds fall back
/// to the sequential scan.
fn thread_budget(n: usize, threads: usize) -> usize {
    let threads = threads.clamp(1, n.max(1));
    if threads > 1 && n >= PAR_MIN_POINTS {
        threads.min(n / PAR_MIN_CHUNK).max(1)
    } else {
        1
    }
}

fn fps_impl(
    xyz: &[[f32; 3]],
    m: usize,
    fg: Option<&[f32]>,
    w0: f32,
    start: usize,
    threads: usize,
) -> Vec<usize> {
    let n = xyz.len();
    check_args(n, m, start, fg);
    let bias = bias_of(fg, w0);
    let nt = thread_budget(n, threads);
    with_arena(|a| {
        let ScratchArena { soa, min_d2, .. } = a;
        soa.fill_from_points(xyz);
        fps_core(soa, m, bias, start, nt, min_d2)
    })
}

fn fps_soa_impl(
    pts: &PointsSoA,
    m: usize,
    fg: Option<&[f32]>,
    w0: f32,
    start: usize,
    threads: usize,
) -> Vec<usize> {
    let n = pts.len();
    check_args(n, m, start, fg);
    let bias = bias_of(fg, w0);
    let nt = thread_budget(n, threads);
    with_arena(|a| fps_core(pts, m, bias, start, nt, &mut a.min_d2))
}

/// Shared SIMD implementation over the arena's rolling `min_d2` buffer.
fn fps_core(
    pts: &PointsSoA,
    m: usize,
    bias: Option<(&[f32], f32)>,
    start: usize,
    nt: usize,
    min_d2: &mut Vec<f32>,
) -> Vec<usize> {
    min_d2.clear();
    min_d2.resize(pts.len(), f32::INFINITY);
    if nt > 1 {
        return fps_parallel(pts, m, bias, start, nt, min_d2);
    }
    let mut out = Vec::with_capacity(m);
    let mut last = start;
    out.push(last);
    for _ in 1..m {
        let chunk_bias = bias.map(|(f, w)| (f, f[last], w));
        let (_, best) =
            scan_chunk_lanes(pts.xs(), pts.ys(), pts.zs(), min_d2, 0, pts.get(last), chunk_bias);
        out.push(best);
        last = best;
    }
    out
}

/// Scan one chunk of the cloud in `[f32; LANES]` blocks: update its
/// `min_d2` slice against the last selected point and return the chunk's
/// running first-max `(value, index)`. `off` is the chunk's offset into the
/// full cloud (`bias.0` is indexed globally).
///
/// Bit-identity with the scalar scan: each lane `l` sees the index
/// subsequence `off+i+l` in order, so its running strict-`>` max is the
/// lane's *first* maximum; combining lanes by (greater value, else smaller
/// index) then yields the first maximum of the whole block prefix, and the
/// scalar tail continues that reduction unchanged.
#[inline]
fn scan_chunk_lanes(
    xs: &[f32],
    ys: &[f32],
    zs: &[f32],
    min_d2: &mut [f32],
    off: usize,
    lp: [f32; 3],
    bias: Option<(&[f32], f32, f32)>, // (fg, fg_last, w0)
) -> (f32, usize) {
    let len = min_d2.len();
    debug_assert!(xs.len() == len && ys.len() == len && zs.len() == len);
    let mut best = off;
    let mut best_v = f32::NEG_INFINITY;
    let mut lane_v = [f32::NEG_INFINITY; LANES];
    let mut lane_i = [0usize; LANES];
    for (l, li) in lane_i.iter_mut().enumerate() {
        *li = off + l;
    }
    let mut i = 0;
    while i + LANES <= len {
        let mut d2 = [0.0f32; LANES];
        for l in 0..LANES {
            let dx = xs[i + l] - lp[0];
            let dy = ys[i + l] - lp[1];
            let dz = zs[i + l] - lp[2];
            d2[l] = dx * dx + dy * dy + dz * dz;
        }
        if let Some((fg, fg_last, w0)) = bias {
            for l in 0..LANES {
                // either-endpoint-foreground indicator (Eq. 1)
                let fg_j = fg[off + i + l];
                let either = fg_j + fg_last - fg_j * fg_last;
                let f = 1.0 + (w0 - 1.0) * either;
                d2[l] *= f * f;
            }
        }
        for l in 0..LANES {
            let md = min_d2[i + l];
            let nmd = if d2[l] < md { d2[l] } else { md };
            min_d2[i + l] = nmd;
            if nmd > lane_v[l] {
                lane_v[l] = nmd;
                lane_i[l] = off + i + l;
            }
        }
        i += LANES;
    }
    for l in 0..LANES {
        if lane_v[l] > best_v || (lane_v[l] == best_v && lane_i[l] < best) {
            best_v = lane_v[l];
            best = lane_i[l];
        }
    }
    for j in i..len {
        let dx = xs[j] - lp[0];
        let dy = ys[j] - lp[1];
        let dz = zs[j] - lp[2];
        let mut d2 = dx * dx + dy * dy + dz * dz;
        if let Some((fg, fg_last, w0)) = bias {
            let fg_j = fg[off + j];
            let either = fg_j + fg_last - fg_j * fg_last;
            let f = 1.0 + (w0 - 1.0) * either;
            d2 *= f * f;
        }
        let md = min_d2[j];
        let nmd = if d2 < md { d2 } else { md };
        min_d2[j] = nmd;
        if nmd > best_v {
            best_v = nmd;
            best = off + j;
        }
    }
    (best_v, best)
}

/// Chunked-parallel scan: `nt` scoped threads each own one contiguous slice
/// of `min_d2`; the caller reduces the per-chunk first-maxima in chunk order
/// between two barriers per iteration.
fn fps_parallel(
    pts: &PointsSoA,
    m: usize,
    bias: Option<(&[f32], f32)>,
    start: usize,
    nt: usize,
    min_d2: &mut [f32],
) -> Vec<usize> {
    let n = pts.len();
    let mut out = Vec::with_capacity(m);
    out.push(start);
    if m == 1 {
        return out;
    }
    let chunk_len = n.div_ceil(nt);
    let chunks: Vec<&mut [f32]> = min_d2.chunks_mut(chunk_len).collect();
    let nt = chunks.len(); // may be fewer than requested
    let last = AtomicUsize::new(start);
    let results: Vec<Mutex<(f32, usize)>> =
        (0..nt).map(|_| Mutex::new((f32::NEG_INFINITY, 0))).collect();
    let barrier = Barrier::new(nt + 1);
    let (xs, ys, zs) = (pts.xs(), pts.ys(), pts.zs());
    std::thread::scope(|scope| {
        for (t, chunk) in chunks.into_iter().enumerate() {
            let (results, barrier, last) = (&results, &barrier, &last);
            scope.spawn(move || {
                let off = t * chunk_len;
                let end = off + chunk.len();
                for _ in 1..m {
                    let cur = last.load(Ordering::Acquire);
                    let chunk_bias = bias.map(|(f, w)| (f, f[cur], w));
                    let lp = [xs[cur], ys[cur], zs[cur]];
                    let r = scan_chunk_lanes(
                        &xs[off..end],
                        &ys[off..end],
                        &zs[off..end],
                        chunk,
                        off,
                        lp,
                        chunk_bias,
                    );
                    *results[t].lock().unwrap() = r;
                    barrier.wait(); // results posted
                    barrier.wait(); // reduction done, `last` updated
                }
            });
        }
        for _ in 1..m {
            barrier.wait();
            let mut best = (f32::NEG_INFINITY, 0usize);
            for r in &results {
                let v = *r.lock().unwrap();
                // strict > keeps the earliest chunk on ties — the same
                // first-max rule as the sequential scan
                if v.0 > best.0 {
                    best = v;
                }
            }
            out.push(best.1);
            last.store(best.1, Ordering::Release);
            barrier.wait();
        }
    });
    out
}

/// Scan one chunk of an interleaved cloud — the original scalar kernel,
/// kept verbatim as the oracle the SIMD lanes are pinned against.
#[inline]
fn scan_chunk(
    xyz: &[[f32; 3]],
    min_d2: &mut [f32],
    off: usize,
    lp: [f32; 3],
    bias: Option<(&[f32], f32, f32)>, // (fg, fg_last, w0)
) -> (f32, usize) {
    let mut best = off;
    let mut best_v = f32::NEG_INFINITY;
    match bias {
        None => {
            for (j, (p, md)) in xyz[off..off + min_d2.len()]
                .iter()
                .zip(min_d2.iter_mut())
                .enumerate()
            {
                let dx = p[0] - lp[0];
                let dy = p[1] - lp[1];
                let dz = p[2] - lp[2];
                let d2 = dx * dx + dy * dy + dz * dz;
                if d2 < *md {
                    *md = d2;
                }
                // first-max tie break, matching jnp.argmax
                if *md > best_v {
                    best_v = *md;
                    best = off + j;
                }
            }
        }
        Some((fg, fg_last, w0)) => {
            for (j, (p, md)) in xyz[off..off + min_d2.len()]
                .iter()
                .zip(min_d2.iter_mut())
                .enumerate()
            {
                let dx = p[0] - lp[0];
                let dy = p[1] - lp[1];
                let dz = p[2] - lp[2];
                let mut d2 = dx * dx + dy * dy + dz * dz;
                // either-endpoint-foreground indicator (Eq. 1)
                let fg_j = fg[off + j];
                let either = fg_j + fg_last - fg_j * fg_last;
                let f = 1.0 + (w0 - 1.0) * either;
                d2 *= f * f;
                if d2 < *md {
                    *md = d2;
                }
                if *md > best_v {
                    best_v = *md;
                    best = off + j;
                }
            }
        }
    }
    (best_v, best)
}

/// Scalar reference FPS (the pre-SIMD sequential implementation) — the
/// oracle the lane kernel is pinned bit-identical to, and the baseline
/// `BENCH_hotpath` measures speedups against. Pass `fg: None, w0: 1.0` for
/// regular FPS.
pub fn fps_scalar(
    xyz: &[[f32; 3]],
    m: usize,
    fg: Option<&[f32]>,
    w0: f32,
    start: usize,
) -> Vec<usize> {
    let n = xyz.len();
    check_args(n, m, start, fg);
    let bias = bias_of(fg, w0);
    let mut out = Vec::with_capacity(m);
    let mut min_d2 = vec![f32::INFINITY; n];
    let mut last = start;
    out.push(last);
    for _ in 1..m {
        let chunk_bias = bias.map(|(f, w)| (f, f[last], w));
        let (_, best) = scan_chunk(xyz, &mut min_d2, 0, xyz[last], chunk_bias);
        out.push(best);
        last = best;
    }
    out
}

/// Fraction of sampled points that are foreground (Fig. 4 statistic).
pub fn fg_fraction(idx: &[usize], fg: &[f32]) -> f32 {
    if idx.is_empty() {
        return 0.0;
    }
    idx.iter().map(|&i| fg[i]).sum::<f32>() / idx.len() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cloud(n: usize, seed: u64) -> Vec<[f32; 3]> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| [r.f32() * 4.0, r.f32() * 4.0, r.f32()]).collect()
    }

    #[test]
    fn indices_distinct_and_start_at_zero() {
        let pts = cloud(500, 1);
        let idx = fps(&pts, 64);
        assert_eq!(idx[0], 0);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 64, "fps must not repeat points");
    }

    #[test]
    fn second_point_is_farthest_from_first() {
        let pts = cloud(300, 2);
        let idx = fps(&pts, 2);
        let p0 = pts[0];
        let d2 = |p: [f32; 3]| {
            (p[0] - p0[0]).powi(2) + (p[1] - p0[1]).powi(2) + (p[2] - p0[2]).powi(2)
        };
        let max = pts.iter().map(|&p| d2(p)).fold(0.0f32, f32::max);
        assert!((d2(pts[idx[1]]) - max).abs() < 1e-6);
    }

    #[test]
    fn coverage_beats_random() {
        // FPS should cover space: max distance from any point to nearest
        // sample is smaller than for the first-m prefix.
        let pts = cloud(1000, 3);
        let idx = fps(&pts, 32);
        let gap = |sel: &[usize]| {
            pts.iter()
                .map(|p| {
                    sel.iter()
                        .map(|&i| {
                            let q = pts[i];
                            (p[0] - q[0]).powi(2) + (p[1] - q[1]).powi(2) + (p[2] - q[2]).powi(2)
                        })
                        .fold(f32::INFINITY, f32::min)
                })
                .fold(0.0f32, f32::max)
        };
        let prefix: Vec<usize> = (0..32).collect();
        assert!(gap(&idx) < gap(&prefix));
    }

    #[test]
    fn bias_increases_fg_fraction() {
        let pts = cloud(800, 4);
        // mark a small cluster as foreground
        let fg: Vec<f32> =
            pts.iter().map(|p| if p[0] < 1.0 && p[1] < 1.0 { 1.0 } else { 0.0 }).collect();
        let base = fg_fraction(&fps(&pts, 128), &fg);
        let biased = fg_fraction(&biased_fps(&pts, 128, &fg, 2.0), &fg);
        let heavy = fg_fraction(&biased_fps(&pts, 128, &fg, 10.0), &fg);
        assert!(biased > base, "w0=2 should sample more fg ({biased} vs {base})");
        assert!(heavy > biased, "w0=10 should sample even more fg");
    }

    #[test]
    fn w0_below_one_deprioritizes_fg() {
        let pts = cloud(800, 5);
        let fg: Vec<f32> = pts.iter().map(|p| if p[0] < 2.0 { 1.0 } else { 0.0 }).collect();
        let base = fg_fraction(&fps(&pts, 128), &fg);
        let depri = fg_fraction(&biased_fps(&pts, 128, &fg, 0.5), &fg);
        assert!(depri < base);
    }

    #[test]
    fn w0_one_equals_regular() {
        let pts = cloud(300, 6);
        let fg = vec![1.0; 300];
        assert_eq!(fps(&pts, 50), biased_fps(&pts, 50, &fg, 1.0));
    }

    #[test]
    fn simd_lanes_bit_identical_to_scalar_oracle() {
        // sizes straddling the lane width (tails of every length) and both
        // bias modes; the SIMD path must reproduce the scalar oracle exactly
        for n in [63usize, 64, 65, 500, 1021] {
            let pts = cloud(n, 40 + n as u64);
            let fg: Vec<f32> =
                pts.iter().map(|p| if p[0] < 1.5 { 1.0 } else { 0.0 }).collect();
            let m = (n / 4).max(2);
            assert_eq!(fps(&pts, m), fps_scalar(&pts, m, None, 1.0, 0), "n={n}");
            assert_eq!(
                biased_fps(&pts, m, &fg, 2.0),
                fps_scalar(&pts, m, Some(&fg), 2.0, 0),
                "biased n={n}"
            );
            assert_eq!(
                fps_from(&pts, m, n / 2),
                fps_scalar(&pts, m, None, 1.0, n / 2),
                "start n={n}"
            );
        }
    }

    #[test]
    fn soa_entry_point_matches_interleaved() {
        let pts = cloud(700, 60);
        let soa = PointsSoA::from_points(&pts);
        let fg: Vec<f32> = pts.iter().map(|p| if p[1] < 2.0 { 1.0 } else { 0.0 }).collect();
        assert_eq!(fps_soa(&soa, 96, 0, 1), fps(&pts, 96));
        assert_eq!(fps_soa(&soa, 96, 350, 1), fps_from(&pts, 96, 350));
        assert_eq!(biased_fps_soa(&soa, 96, &fg, 2.0, 0, 1), biased_fps(&pts, 96, &fg, 2.0));
    }

    #[test]
    fn thread_budget_is_clamped() {
        // threads == 0 and absurd budgets must both match the sequential
        // result (clamped to the point count, then the small-cloud floor)
        let pts = cloud(PAR_MIN_POINTS + 133, 70);
        let seq = fps(&pts, 48);
        assert_eq!(fps_par(&pts, 48, 0), seq, "threads=0");
        assert_eq!(fps_par(&pts, 48, usize::MAX), seq, "threads=usize::MAX");
        let small = cloud(200, 71);
        assert_eq!(fps_par(&small, 16, 0), fps(&small, 16), "small cloud threads=0");
        assert_eq!(fps_par(&small, 16, 999), fps(&small, 16), "small cloud threads=999");
    }

    #[test]
    fn parallel_scan_is_bit_identical_to_sequential() {
        // large enough to clear PAR_MIN_POINTS; try several thread counts,
        // with and without bias, with odd/even chunk splits
        for (n, seed) in [(PAR_MIN_POINTS, 7u64), (PAR_MIN_POINTS + 533, 8u64)] {
            let pts = cloud(n, seed);
            let fg: Vec<f32> =
                pts.iter().map(|p| if p[0] < 1.5 { 1.0 } else { 0.0 }).collect();
            let seq = fps(&pts, 96);
            let seq_b = biased_fps(&pts, 96, &fg, 2.0);
            let seq_s = fps_from(&pts, 96, n / 2);
            for threads in [2, 3, 4, 7] {
                assert_eq!(fps_par(&pts, 96, threads), seq, "threads={threads}");
                assert_eq!(
                    biased_fps_par(&pts, 96, &fg, 2.0, threads),
                    seq_b,
                    "biased threads={threads}"
                );
                assert_eq!(
                    fps_from_par(&pts, 96, n / 2, threads),
                    seq_s,
                    "start threads={threads}"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "start=300 out of range")]
    fn out_of_range_start_rejected() {
        let pts = cloud(300, 9);
        fps_from(&pts, 8, 300);
    }
}

#[cfg(test)]
mod start_tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn fps_from_starts_at_given_index() {
        let mut r = Rng::new(8);
        let pts: Vec<[f32; 3]> = (0..200).map(|_| [r.f32(), r.f32(), r.f32()]).collect();
        let idx = fps_from(&pts, 16, 100);
        assert_eq!(idx[0], 100);
    }

    #[test]
    fn different_starts_decorrelate_views() {
        // the PointSplit fix: two regular-FPS pipelines from different
        // starts must not sample identical sets
        let mut r = Rng::new(9);
        let pts: Vec<[f32; 3]> = (0..500).map(|_| [r.f32() * 4.0, r.f32() * 4.0, r.f32()]).collect();
        let a = fps_from(&pts, 64, 0);
        let b = fps_from(&pts, 64, 250);
        let overlap = a.iter().filter(|i| b.contains(i)).count();
        assert!(overlap < 60, "views nearly identical: {overlap}/64 shared");
    }
}
