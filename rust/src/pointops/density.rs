//! Density-based biased sampling — the paper's §7.1 generalization.
//!
//! "A point sampling technique has its own metric (e.g., distance or
//! density) and our technique is applied ... by slightly modifying the
//! metric with point semantics. In case of a density-based sampling
//! technique we can simply boost a point's density-based metric value if
//! the point is in a specific group."
//!
//! Implementation: each point's base score is its inverse local density
//! (sparse regions first, as in density-aware completion samplers);
//! foreground points get their score multiplied by `w0`. Selection is
//! greedy with neighborhood suppression so samples stay spread out.

use std::collections::HashMap;

/// Local density: neighbor count within `radius` (grid-accelerated).
pub fn local_density(xyz: &[[f32; 3]], radius: f32) -> Vec<u32> {
    let cell = radius;
    let key = |p: &[f32; 3]| {
        (
            (p[0] / cell).floor() as i32,
            (p[1] / cell).floor() as i32,
            (p[2] / cell).floor() as i32,
        )
    };
    let mut cells: HashMap<(i32, i32, i32), Vec<u32>> = HashMap::new();
    for (i, p) in xyz.iter().enumerate() {
        cells.entry(key(p)).or_default().push(i as u32);
    }
    let r2 = radius * radius;
    xyz.iter()
        .map(|p| {
            let (kx, ky, kz) = key(p);
            let mut count = 0u32;
            for dx in -1..=1 {
                for dy in -1..=1 {
                    for dz in -1..=1 {
                        if let Some(v) = cells.get(&(kx + dx, ky + dy, kz + dz)) {
                            for &j in v {
                                let q = xyz[j as usize];
                                let d2 = (q[0] - p[0]).powi(2)
                                    + (q[1] - p[1]).powi(2)
                                    + (q[2] - p[2]).powi(2);
                                if d2 <= r2 {
                                    count += 1;
                                }
                            }
                        }
                    }
                }
            }
            count
        })
        .collect()
}

/// Density-based biased sampling: pick `m` points maximizing
/// `w(fg) / density`, suppressing already-covered neighborhoods.
pub fn density_biased_sample(
    xyz: &[[f32; 3]],
    m: usize,
    fg: &[f32],
    w0: f32,
    radius: f32,
) -> Vec<usize> {
    assert!(m <= xyz.len());
    let density = local_density(xyz, radius);
    let mut score: Vec<f32> = density
        .iter()
        .zip(fg.iter())
        .map(|(&d, &f)| {
            let w = 1.0 + (w0 - 1.0) * f;
            w / (d as f32).max(1.0)
        })
        .collect();
    let r2 = radius * radius;
    let mut out = Vec::with_capacity(m);
    for _ in 0..m {
        // first-max tie break for determinism
        let mut best = 0;
        for (i, &s) in score.iter().enumerate() {
            if s > score[best] {
                best = i;
            }
        }
        if score[best] <= f32::NEG_INFINITY {
            break;
        }
        out.push(best);
        let bp = xyz[best];
        // suppress the picked point and damp its neighborhood so selection
        // spreads (the density analog of FPS's min-distance update)
        score[best] = f32::NEG_INFINITY;
        for (i, p) in xyz.iter().enumerate() {
            let d2 =
                (p[0] - bp[0]).powi(2) + (p[1] - bp[1]).powi(2) + (p[2] - bp[2]).powi(2);
            if d2 <= r2 && score[i].is_finite() {
                score[i] *= 0.25;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cloud(n: usize, seed: u64) -> Vec<[f32; 3]> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| [r.f32() * 4.0, r.f32() * 4.0, r.f32()]).collect()
    }

    #[test]
    fn density_counts_self() {
        let pts = vec![[0.0f32; 3], [10.0, 0.0, 0.0]];
        let d = local_density(&pts, 0.5);
        assert_eq!(d, vec![1, 1]);
    }

    #[test]
    fn denser_regions_have_higher_density() {
        let mut pts = cloud(200, 1);
        // add a tight cluster
        for i in 0..50 {
            pts.push([2.0 + 0.001 * i as f32, 2.0, 0.5]);
        }
        let d = local_density(&pts, 0.3);
        let cluster_mean: f32 = d[200..].iter().map(|&x| x as f32).sum::<f32>() / 50.0;
        let spread_mean: f32 = d[..200].iter().map(|&x| x as f32).sum::<f32>() / 200.0;
        assert!(cluster_mean > 2.0 * spread_mean);
    }

    #[test]
    fn indices_distinct() {
        let pts = cloud(300, 2);
        let fg = vec![0.0; 300];
        let idx = density_biased_sample(&pts, 64, &fg, 1.0, 0.4);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 64);
    }

    #[test]
    fn fg_boost_increases_fg_share() {
        let pts = cloud(600, 3);
        let fg: Vec<f32> =
            pts.iter().map(|p| if p[0] < 1.5 { 1.0 } else { 0.0 }).collect();
        let share = |idx: &[usize]| {
            idx.iter().map(|&i| fg[i]).sum::<f32>() / idx.len() as f32
        };
        let base = share(&density_biased_sample(&pts, 96, &fg, 1.0, 0.4));
        let boosted = share(&density_biased_sample(&pts, 96, &fg, 4.0, 0.4));
        assert!(boosted > base, "boosted {boosted} <= base {base}");
    }

    #[test]
    fn prefers_sparse_regions_at_w0_one() {
        let mut pts = cloud(100, 4);
        for i in 0..100 {
            pts.push([2.0 + 0.002 * (i % 10) as f32, 2.0 + 0.002 * (i / 10) as f32, 0.5]);
        }
        let fg = vec![0.0; 200];
        let idx = density_biased_sample(&pts, 40, &fg, 1.0, 0.4);
        let sparse_hits = idx.iter().filter(|&&i| i < 100).count();
        assert!(sparse_hits > 20, "sparse region undersampled: {sparse_hits}/40");
    }
}
