//! Vendored, dependency-free subset of the `anyhow` error-handling crate.
//!
//! The repository builds offline, so instead of pulling `anyhow` from
//! crates.io we vendor the small slice of its API the codebase uses:
//!
//! - [`Error`]: an opaque error value holding a context chain
//! - [`Result`]: `std::result::Result` defaulted to [`Error`]
//! - [`anyhow!`] / [`bail!`]: format-style constructors
//! - [`Context`]: `.context(..)` / `.with_context(..)` on results/options
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error` — that is what makes the blanket
//! `From<E: std::error::Error>` conversion coherent. Display `{}` prints the
//! outermost message; alternate `{:#}` prints the whole chain separated by
//! `: `, and `{:?}` prints the chain as a `Caused by:` list.

use std::fmt;

/// Opaque error: an outermost message plus the chain of underlying causes.
pub struct Error {
    /// `chain[0]` is the outermost (most recent) context.
    chain: Vec<String>,
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error in an additional layer of context.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if f.alternate() {
            for cause in &self.chain[1..] {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }
}

/// Attach context to fallible computations (mirrors `anyhow::Context`).
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::{Context, Error, Result};

    fn io_fail() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing"))
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = io_fail().with_context(|| "reading config".to_string()).unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing thing");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            io_fail()?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "missing thing");
    }

    #[test]
    fn macro_formats() {
        let x = 3;
        let e = anyhow!("bad value {x} ({})", "units");
        assert_eq!(format!("{e}"), "bad value 3 (units)");
    }

    #[test]
    fn bail_returns_early() {
        fn inner(flag: bool) -> Result<u32> {
            if flag {
                bail!("flagged");
            }
            Ok(7)
        }
        assert_eq!(inner(false).unwrap(), 7);
        assert_eq!(format!("{}", inner(true).unwrap_err()), "flagged");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(format!("{e}"), "empty");
    }
}
