//! Paper Fig. 9: per-scene latency and peak memory of the six schemes on
//! both datasets.
//!
//! Expected shape: PointPainting(FP32, GPU-only/TF) is the slowest and most
//! memory-hungry by far; INT8/TFLite schemes cluster low; PointSplit(INT8)
//! is fastest overall — 11.4x (synrgbd) / 24.7x (synscan) vs the FP32
//! GPU-only fusion baseline.

mod common;

use pointsplit::bench::Table;
use pointsplit::coordinator::{DetectorConfig, ScenePipeline, Schedule, Variant};
use pointsplit::data;
use pointsplit::runtime::Runtime;
use pointsplit::sim::DeviceKind;

fn schemes() -> Vec<(&'static str, Variant, bool, Schedule)> {
    let gpu = Schedule::SingleDevice(DeviceKind::Gpu);
    let gpu_cpu = Schedule::Sequential { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::Cpu };
    let seq = Schedule::Sequential { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu };
    let split = Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu };
    vec![
        ("VoteNet (FP32, GPU)", Variant::VoteNet, false, gpu),
        ("PointPainting (FP32, GPU)", Variant::PointPainting, false, gpu),
        ("PointPainting (INT8, GPU-CPU)", Variant::PointPainting, true, gpu_cpu),
        ("VoteNet (INT8, GPU-TPU)", Variant::VoteNet, true, seq),
        ("PointPainting (INT8, GPU-TPU)", Variant::PointPainting, true, seq),
        ("PointSplit (INT8, GPU-TPU)", Variant::PointSplit, true, split),
    ]
}

fn run_dataset(rt: &Runtime, ds_name: &str, scenes: usize) {
    let ds = data::dataset(ds_name).unwrap();
    let mut t = Table::new(&["scheme", "latency (ms)", "peak mem (MB)"]);
    let mut baseline = 0.0;
    let mut best = f64::INFINITY;
    for (name, variant, int8, sched) in schemes() {
        let cfg = DetectorConfig::new(ds_name, variant, int8, sched);
        let pipe = ScenePipeline::new(rt, cfg);
        let mut lat = 0.0;
        let mut mem: f64 = 0.0;
        for seed in 0..scenes as u64 {
            let scene = data::generate_scene(60_000 + seed, ds);
            let out = pipe.run(&scene, seed).expect("pipeline");
            lat += out.timeline.total_ms;
            mem = mem.max(out.peak_memory_mb);
        }
        lat /= scenes as f64;
        if name.starts_with("PointPainting (FP32") {
            baseline = lat;
        }
        if name.starts_with("PointSplit") {
            best = lat;
        }
        t.row(vec![name.into(), format!("{lat:.0}"), format!("{mem:.0}")]);
    }
    t.print(&format!("Fig. 9 — per-scene latency + peak memory on {ds_name} ({scenes} scenes)"));
    println!(
        "speedup PointSplit(INT8) vs PointPainting(FP32, GPU-only): {:.1}x (paper: {})",
        baseline / best,
        if ds_name == "synrgbd" { "11.4x" } else { "24.7x" }
    );
}

fn main() {
    let rt = common::open_runtime();
    let scenes = common::scene_budget(4);
    for ds in ["synrgbd", "synscan"] {
        run_dataset(&rt, ds, scenes);
    }
}
