//! Heterogeneous-accelerator timing model (Jetson Nano GPU + Coral EdgeTPU
//! + ARM CPU + PCIe Gen2 x1), calibrated against the paper's own measured
//! per-layer latencies (Tables 12/13).
//!
//! **Substitution note (DESIGN.md §2):** we have no Jetson/EdgeTPU. Every
//! stage still executes *functionally* (PJRT CPU / Rust pointops); this
//! module supplies the paper-comparable *timing* via an analytical roofline
//! model: `t = dispatch_overhead + flops/throughput + bytes/mem_bw`, plus a
//! per-transfer interconnect cost when a stage consumes data produced on a
//! different device. Constants are fitted so the sequential INT8 per-layer
//! latencies reproduce paper Table 12 within the mini-model's workload shape.

pub mod device;
pub mod schedule;

pub use device::{Device, DeviceKind, Precision, Workload, WorkloadKind};
pub use schedule::{cost_of, PlanCost, ScheduleSim, StageSpec, Timeline};
