//! Integration tests for the host-parallel stage executor.
//!
//! Everything here runs offline: the synthetic runtime executes NN stages on
//! the deterministic host surrogate, so the full functional pipeline —
//! detections included — is exercised without artifacts or a PJRT backend.
//!
//! The core contracts:
//! 1. **Determinism** — parallel execution produces bit-identical detections
//!    and identical `StageSpec` DAGs to sequential execution, for every
//!    variant (property over seeds).
//! 2. **The merge() dependency fix** — `sa4_pm` depends on *both*
//!    pipelines' SA3 NN stages and never starts before either finishes in
//!    the simulated timeline. (On the pre-fix code the dep list held only
//!    the max stage index, so the structural assertion below fails there.)
//! 3. **SIMD bit-identity** — the SoA lane kernels the pipeline runs match
//!    the retained scalar oracles exactly, over the same seed set the
//!    determinism property uses.
//! 4. **Steady-state allocation freedom** — after warm-up, running scenes
//!    through the worker pool leaves the scratch-arena allocation counter
//!    flat (the per-scene path reuses per-worker arenas).

use pointsplit::coordinator::{DetectorConfig, ScenePipeline, Schedule, Variant};
use pointsplit::data::{self, generate_scene, SYNRGBD};
use pointsplit::exec::HostExec;
use pointsplit::pointops;
use pointsplit::runtime::Runtime;
use pointsplit::serving::dispatch::PipelineExecutor;
use pointsplit::serving::{
    run_traffic, ArrivalPattern, BatchPolicy, LoadGen, Request, ServicePlanner, SloPolicy,
    TrafficScenario,
};
use pointsplit::sim::DeviceKind;
use pointsplit::util::tensor::Tensor;

const VARIANTS: [Variant; 4] =
    [Variant::VoteNet, Variant::PointPainting, Variant::RandomSplit, Variant::PointSplit];

fn pipelined() -> Schedule {
    Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu }
}

fn cfg(variant: Variant, schedule: Schedule) -> DetectorConfig {
    DetectorConfig::new("synrgbd", variant, true, schedule)
}

#[test]
fn parallel_execution_bit_identical_to_sequential_all_variants() {
    let rt = Runtime::synthetic();
    for variant in VARIANTS {
        for seed in [1u64, 42, 1234] {
            let scene = generate_scene(seed, &SYNRGBD);
            let seq = ScenePipeline::new(&rt, cfg(variant, pipelined()))
                .with_host_exec(HostExec::Sequential)
                .run(&scene, seed)
                .expect("sequential run");
            assert!(
                !seq.stage_specs.is_empty(),
                "{variant:?}: pipeline must declare stages"
            );
            for threads in [2usize, 4, 8] {
                let par = ScenePipeline::new(&rt, cfg(variant, pipelined()))
                    .with_host_exec(HostExec::Parallel { threads })
                    .run(&scene, seed)
                    .expect("parallel run");
                assert_eq!(
                    seq.detections, par.detections,
                    "{variant:?} seed {seed} threads {threads}: detections diverged"
                );
                assert_eq!(
                    seq.stage_specs, par.stage_specs,
                    "{variant:?} seed {seed} threads {threads}: stage DAG diverged"
                );
                assert_eq!(
                    seq.timeline.total_ms.to_bits(),
                    par.timeline.total_ms.to_bits(),
                    "{variant:?} seed {seed} threads {threads}: simulated timeline diverged"
                );
            }
        }
    }
}

#[test]
fn parallel_execution_bit_identical_across_schedules() {
    let rt = Runtime::synthetic();
    for schedule in [
        pipelined(),
        Schedule::Sequential { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
        Schedule::SingleDevice(DeviceKind::Gpu),
    ] {
        let scene = generate_scene(7, &SYNRGBD);
        let seq = ScenePipeline::new(&rt, cfg(Variant::PointSplit, schedule))
            .with_host_exec(HostExec::Sequential)
            .run(&scene, 7)
            .unwrap();
        let par = ScenePipeline::new(&rt, cfg(Variant::PointSplit, schedule))
            .with_host_exec(HostExec::Parallel { threads: 4 })
            .run(&scene, 7)
            .unwrap();
        assert_eq!(seq.detections, par.detections, "{schedule:?}");
        assert_eq!(seq.stage_specs, par.stage_specs, "{schedule:?}");
    }
}

/// The merge() dependency regression: `sa4_pm` must wait for **both**
/// pipelines' SA3 NN stages — structurally (dep edges) and in the simulated
/// timeline. The old code kept only `max(a.last_nn, b.last_nn)`.
#[test]
fn sa4_waits_for_both_pipelines() {
    let rt = Runtime::synthetic();
    let scene = generate_scene(3, &SYNRGBD);
    let out = ScenePipeline::new(&rt, cfg(Variant::PointSplit, pipelined()))
        .run(&scene, 3)
        .unwrap();
    let idx = |name: &str| {
        out.stage_specs
            .iter()
            .position(|s| s.name == name)
            .unwrap_or_else(|| panic!("stage '{name}' missing"))
    };
    let (nn_a, nn_b, pm4) = (idx("sa3_normal_nn"), idx("sa3_bias_nn"), idx("sa4_pm"));
    let deps = &out.stage_specs[pm4].deps;
    assert!(
        deps.contains(&nn_a) && deps.contains(&nn_b),
        "sa4_pm deps {deps:?} must include both sa3 NN stages ({nn_a}, {nn_b})"
    );
    // and the simulated timeline must respect it
    let t = |name: &str| out.timeline.stage(name).unwrap_or_else(|| panic!("{name} interval"));
    let pm4_start = t("sa4_pm").compute_start_ms;
    assert!(
        pm4_start >= t("sa3_normal_nn").end_ms - 1e-9
            && pm4_start >= t("sa3_bias_nn").end_ms - 1e-9,
        "sa4_pm at {pm4_start} started before an SA3 NN finished ({} / {})",
        t("sa3_normal_nn").end_ms,
        t("sa3_bias_nn").end_ms
    );
}

/// Same property on the serving planner's mirrored DAG.
#[test]
fn planner_sa4_waits_for_both_pipelines() {
    let planner = ServicePlanner::synthetic();
    let stages = planner.stages(&cfg(Variant::PointSplit, pipelined()), 2048, false).unwrap();
    let idx = |name: &str| stages.iter().position(|s| s.name == name).unwrap();
    let deps = &stages[idx("sa4_pm")].deps;
    assert!(
        deps.contains(&idx("sa3_normal_nn")) && deps.contains(&idx("sa3_bias_nn")),
        "planner sa4_pm deps {deps:?}"
    );
}

/// The pipeline's recorded DAG and the serving planner's analytic DAG are
/// the same object — any drift between them is a bug (this is the class the
/// merge() bug belonged to).
#[test]
fn pipeline_dag_matches_serving_planner() {
    let rt = Runtime::synthetic();
    let planner = ServicePlanner::synthetic();
    for variant in VARIANTS {
        let c = cfg(variant, pipelined());
        let scene = generate_scene(11, &SYNRGBD);
        let out = ScenePipeline::new(&rt, c.clone()).run(&scene, 11).unwrap();
        let planned = planner.stages(&c, SYNRGBD.num_points, false).unwrap();
        assert_eq!(planned, out.stage_specs, "{variant:?}: planner DAG drifted from pipeline");
    }
}

#[test]
fn consecutive_matching_skips_seg_stage() {
    let rt = Runtime::synthetic();
    let pipe = ScenePipeline::new(&rt, cfg(Variant::PointSplit, pipelined()));
    let scene = generate_scene(5, &SYNRGBD);
    let (first, scores) = pipe.run_with_scores(&scene, 5, None).unwrap();
    assert!(first.stage_specs.iter().any(|s| s.name == "seg"));
    let scores = scores.expect("painted run returns scores");
    let (second, _) = pipe.run_with_scores(&scene, 5, Some(&scores)).unwrap();
    assert!(
        !second.stage_specs.iter().any(|s| s.name == "seg"),
        "consecutive matching must skip the segmenter"
    );
    assert!(second.timeline.total_ms < first.timeline.total_ms + 1e-9);
    // determinism holds on the skip path too
    let (second_par, _) = pipe.run_with_scores(&scene, 5, Some(&scores)).unwrap();
    assert_eq!(second.detections, second_par.detections);
}

/// End-to-end functional serving on the synthetic runtime: the per-scene
/// worker pool executes dispatched batches and the report carries mAP.
#[test]
fn traffic_gateway_executes_functionally_offline() {
    let planner = ServicePlanner::synthetic();
    let c = cfg(Variant::PointSplit, pipelined());
    let ds = data::dataset("synrgbd").unwrap();
    let cap = planner.capacity_rps(&c, ds.num_points, 2).unwrap();
    let sc = TrafficScenario {
        name: "functional-offline".into(),
        configs: vec![c],
        num_points: ds.num_points,
        load: LoadGen::simple(
            ArrivalPattern::Poisson { rate_rps: cap * 0.5 },
            4_000.0,
            2_000.0,
            13,
        ),
        queue_capacity: 16,
        batch: BatchPolicy { max_batch: 2, max_wait_ms: 25.0 },
        policy: SloPolicy::None,
    };
    let rt = Runtime::synthetic();
    let exec = PipelineExecutor::with_workers(&rt, ds, 2);
    let rep = run_traffic(&sc, &planner, Some(&exec)).unwrap();
    assert!(rep.completed > 0, "no requests completed");
    assert!(
        rep.map_25.is_some(),
        "functional execution must report mAP on the surrogate backend"
    );
}

/// The SIMD lane kernels the pipeline actually runs are bit-identical to
/// the retained scalar oracles, on real generated scenes over the same
/// seeds the determinism property uses (the unit suites pin synthetic
/// clouds; this pins the production data path).
#[test]
fn simd_kernels_bit_identical_to_scalar_oracles() {
    for seed in [1u64, 42, 1234] {
        let scene = generate_scene(seed, &SYNRGBD);
        let pts = &scene.points;
        let fg: Vec<f32> =
            scene.point_obj.iter().map(|&o| if o >= 0 { 1.0 } else { 0.0 }).collect();
        let m = 256;
        let start = pts.len() / 2;
        assert_eq!(
            pointops::fps(pts, m),
            pointops::fps_scalar(pts, m, None, 1.0, 0),
            "fps diverged from the scalar oracle (seed {seed})"
        );
        assert_eq!(
            pointops::biased_fps_from(pts, m, &fg, 2.0, start),
            pointops::fps_scalar(pts, m, Some(&fg), 2.0, start),
            "biased fps diverged from the scalar oracle (seed {seed})"
        );
        let centers = pointops::fps(pts, m);
        assert_eq!(
            pointops::ball_query(pts, &centers, 0.3, 32),
            pointops::ball_query_scalar(pts, &centers, 0.3, 32),
            "ball_query diverged from the scalar oracle (seed {seed})"
        );
        let src: Vec<[f32; 3]> = centers.iter().map(|&i| pts[i]).collect();
        let mut feats = Tensor::zeros(vec![src.len(), 8]);
        for (i, v) in feats.data.iter_mut().enumerate() {
            *v = (i % 97) as f32 * 0.25 - 12.0;
        }
        let simd = pointops::three_nn_interpolate(pts, &src, &feats);
        let oracle = pointops::three_nn_interpolate_scalar(pts, &src, &feats);
        assert_eq!(simd.shape, oracle.shape);
        for (i, (a, b)) in simd.data.iter().zip(oracle.data.iter()).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "three_nn diverged from the scalar oracle at element {i} (seed {seed})"
            );
        }
    }
}

/// Degenerate thread budgets at the public API level: zero and absurdly
/// large counts are clamped, never panic, and return the sequential result
/// (the unit suites cover the clamp arithmetic; this pins the entry points).
#[test]
fn degenerate_thread_budgets_are_clamped_at_the_api() {
    let scene = generate_scene(42, &SYNRGBD);
    let pts = &scene.points;
    let base = pointops::fps(pts, 128);
    for threads in [0usize, usize::MAX] {
        assert_eq!(pointops::fps_par(pts, 128, threads), base, "fps_par threads={threads}");
    }
    let centers = &base[..16]; // < par threshold: the clamp still applies
    let groups = pointops::ball_query(pts, centers, 0.3, 16);
    for threads in [0usize, usize::MAX] {
        assert_eq!(
            pointops::ball_query_par(pts, centers, 0.3, 16, threads),
            groups,
            "ball_query_par threads={threads}"
        );
    }
    let dst: Vec<[f32; 3]> = pts[..100].to_vec();
    let src: Vec<[f32; 3]> = base.iter().map(|&i| pts[i]).collect();
    let feats = Tensor::zeros(vec![src.len(), 8]);
    let out = pointops::three_nn_interpolate(&dst, &src, &feats);
    for threads in [0usize, usize::MAX] {
        assert_eq!(
            pointops::three_nn_interpolate_par(&dst, &src, &feats, threads),
            out,
            "three_nn_interpolate_par threads={threads}"
        );
    }
}

/// Satellite acceptance: after warm-up, pushing scenes through the worker
/// pool leaves the scratch allocation counter flat — the per-scene hot path
/// reuses each worker's arena instead of allocating. Retries tolerate other
/// tests growing *their* thread arenas concurrently; a correct
/// implementation reaches a flat window, a regressing one never does.
#[test]
fn steady_state_scenes_do_not_grow_scratch_arenas() {
    let rt = Runtime::synthetic();
    let ds = data::dataset("synrgbd").unwrap();
    let exec = PipelineExecutor::with_workers(&rt, ds, 2);
    let c = cfg(Variant::PointSplit, pipelined());
    let batch = |lo: u64| -> Vec<Request> {
        (0..4)
            .map(|i| Request {
                id: lo + i,
                arrival_ms: 0.0,
                deadline_ms: f64::MAX,
                seed: lo + i,
                class: 0,
                key: 0,
                client: 0,
            })
            .collect()
    };
    // warm-up: workers pre-size their arenas at spawn (`warm(ds.num_points)`)
    // and the first batches grow whatever the exact workload still needs
    exec.execute(&c, &batch(0)).expect("warm-up batch");
    exec.execute(&c, &batch(4)).expect("warm-up batch");
    let mut flat = false;
    for round in 0..8u64 {
        let before = pointops::scratch_tracker().alloc_count();
        exec.execute(&c, &batch(8 + 4 * round)).expect("steady-state batch");
        if pointops::scratch_tracker().alloc_count() == before {
            flat = true;
            break;
        }
    }
    assert!(flat, "scratch arenas kept growing after warm-up: the per-scene path allocates");
}
