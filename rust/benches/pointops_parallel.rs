//! §Perf: sequential vs parallel host execution of 3D feature extraction.
//!
//! Pillar (2) of the paper is *parallelized 3D feature extraction*; this
//! bench records what the host-side analogue buys us, at two levels:
//!
//! 1. op level — scalar-oracle vs SIMD-lane vs thread-parallel FPS, ball
//!    query, and grid-accelerated 3-NN interpolation on a large synthetic
//!    cloud;
//! 2. pipeline level — the full PointSplit scene pipeline run sequentially
//!    vs DAG-parallel (`host_ms`, the acceptance metric).
//!
//! Results are persisted to `BENCH_hotpath.json` (section
//! `pointops_parallel`, merged alongside `perf_hotpath`).
//!
//! Runs offline on the synthetic runtime (deterministic host surrogate for
//! NN stages). Knobs:
//!   POINTSPLIT_BENCH_SCENES   pipeline iterations   (default 4, CI: 1)
//!   POINTSPLIT_BENCH_POINTS   cloud size            (default 32768)
//!   POINTSPLIT_BENCH_THREADS  thread budget         (default: host cores)
//!   POINTSPLIT_BENCH_ASSERT   if set, fail below 1.5x pipeline speedup

mod common;

use std::time::Instant;

use pointsplit::bench::{bench_fn, f1, f2, update_bench_json, BenchResult, Table};
use pointsplit::coordinator::{DetectorConfig, ScenePipeline, Schedule, Variant};
use pointsplit::data::{generate_scene, DatasetCfg, SYNRGBD};
use pointsplit::exec::HostExec;
use pointsplit::pointops;
use pointsplit::runtime::Runtime;
use pointsplit::sim::DeviceKind;
use pointsplit::util::json::Json;
use pointsplit::util::rng::Rng;
use pointsplit::util::tensor::Tensor;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn op_row(scalar: &BenchResult, seq: &BenchResult, par: &BenchResult) -> Json {
    Json::obj(vec![
        ("scalar_ms", Json::Num(scalar.mean_us / 1e3)),
        ("seq_ms", Json::Num(seq.mean_us / 1e3)),
        ("par_ms", Json::Num(par.mean_us / 1e3)),
    ])
}

fn main() {
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let threads = env_usize("POINTSPLIT_BENCH_THREADS", cores);
    let n = env_usize("POINTSPLIT_BENCH_POINTS", 32_768);
    let scenes = common::scene_budget(4);
    println!(
        "=== pointops_parallel: host parallelism ({cores} cores, {threads} threads, \
         n={n}) ===\n"
    );

    // ------------------------------------------------------------ op level
    let mut rng = Rng::new(7);
    let cloud: Vec<[f32; 3]> = (0..n)
        .map(|_| [rng.f32() * 8.0, rng.f32() * 8.0, rng.f32() * 2.5])
        .collect();
    let fg: Vec<f32> = cloud.iter().map(|p| if p[0] < 2.0 { 1.0 } else { 0.0 }).collect();
    let m = (n / 4).clamp(1, 512);

    let fps_scalar = bench_fn(&format!("fps {n}->{m} scalar"), 1, 3, || {
        std::hint::black_box(pointops::fps_scalar(&cloud, m, None, 1.0, 0));
    });
    fps_scalar.print();
    let fps_seq = bench_fn(&format!("fps {n}->{m} simd seq"), 1, 3, || {
        std::hint::black_box(pointops::fps(&cloud, m));
    });
    fps_seq.print();
    let fps_par = bench_fn(&format!("fps {n}->{m} simd par x{threads}"), 1, 3, || {
        std::hint::black_box(pointops::fps_par(&cloud, m, threads));
    });
    fps_par.print();
    let bfps_scalar = bench_fn(&format!("biased_fps {n}->{m} scalar"), 1, 3, || {
        std::hint::black_box(pointops::fps_scalar(&cloud, m, Some(&fg), 2.0, 0));
    });
    bfps_scalar.print();
    let bfps_seq = bench_fn(&format!("biased_fps {n}->{m} simd seq"), 1, 3, || {
        std::hint::black_box(pointops::biased_fps(&cloud, m, &fg, 2.0));
    });
    bfps_seq.print();
    let bfps_par = bench_fn(&format!("biased_fps {n}->{m} simd par x{threads}"), 1, 3, || {
        std::hint::black_box(pointops::biased_fps_par(&cloud, m, &fg, 2.0, threads));
    });
    bfps_par.print();

    let centers = pointops::fps_par(&cloud, m, threads);
    let bq_scalar = bench_fn(&format!("ball_query {n}x{m} k=32 scalar"), 1, 5, || {
        std::hint::black_box(pointops::ball_query_scalar(&cloud, &centers, 0.4, 32));
    });
    bq_scalar.print();
    let bq_seq = bench_fn(&format!("ball_query {n}x{m} k=32 simd seq"), 1, 5, || {
        std::hint::black_box(pointops::ball_query(&cloud, &centers, 0.4, 32));
    });
    bq_seq.print();
    let bq_par = bench_fn(&format!("ball_query {n}x{m} k=32 simd par x{threads}"), 1, 5, || {
        std::hint::black_box(pointops::ball_query_par(&cloud, &centers, 0.4, 32, threads));
    });
    bq_par.print();

    let src: Vec<[f32; 3]> = centers.iter().map(|&i| cloud[i]).collect();
    let feats = Tensor::zeros(vec![src.len(), 128]);
    let in_brute = bench_fn(&format!("three_nn {n}<-{m} brute"), 1, 3, || {
        std::hint::black_box(pointsplit::pointops::interp::three_nn_interpolate_bruteforce(
            &cloud, &src, &feats,
        ));
    });
    in_brute.print();
    let in_scalar = bench_fn(&format!("three_nn {n}<-{m} grid scalar"), 1, 5, || {
        std::hint::black_box(pointops::three_nn_interpolate_scalar(&cloud, &src, &feats));
    });
    in_scalar.print();
    let in_grid = bench_fn(&format!("three_nn {n}<-{m} grid simd seq"), 1, 5, || {
        std::hint::black_box(pointops::three_nn_interpolate(&cloud, &src, &feats));
    });
    in_grid.print();
    let in_par = bench_fn(&format!("three_nn {n}<-{m} grid simd par x{threads}"), 1, 5, || {
        std::hint::black_box(pointops::three_nn_interpolate_par(&cloud, &src, &feats, threads));
    });
    in_par.print();

    let mut ops = Table::new(&["op", "scalar ms", "simd ms", "par ms", "par speedup"]);
    for (name, sc, a, b) in [
        ("fps", &fps_scalar, &fps_seq, &fps_par),
        ("biased_fps", &bfps_scalar, &bfps_seq, &bfps_par),
        ("ball_query", &bq_scalar, &bq_seq, &bq_par),
        ("three_nn (brute base)", &in_brute, &in_grid, &in_par),
        ("three_nn (grid base)", &in_scalar, &in_grid, &in_par),
    ] {
        ops.row(vec![
            name.to_string(),
            f2(sc.mean_us / 1e3),
            f2(a.mean_us / 1e3),
            f2(b.mean_us / 1e3),
            f2(sc.mean_us / b.mean_us),
        ]);
    }
    ops.print("op-level: scalar oracle vs SIMD vs parallel");

    // ------------------------------------------------------ pipeline level
    let ds = DatasetCfg { name: "bench", num_points: n, ..SYNRGBD };
    let rt = Runtime::synthetic();
    let cfg = DetectorConfig::new(
        "synrgbd",
        Variant::PointSplit,
        true,
        Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
    );
    let seq_pipe =
        ScenePipeline::new(&rt, cfg.clone()).with_host_exec(HostExec::Sequential);
    let par_pipe = ScenePipeline::new(&rt, cfg)
        .with_host_exec(HostExec::Parallel { threads });

    let run_ms = |pipe: &ScenePipeline, label: &str| -> f64 {
        let mut total = 0.0;
        for s in 0..scenes {
            let scene = generate_scene(100 + s as u64, &ds);
            let t = Instant::now();
            let out = pipe.run(&scene, 100 + s as u64).expect("pipeline");
            let wall = t.elapsed().as_secs_f64() * 1e3;
            total += out.host_ms;
            println!(
                "  {label} scene {s}: host {:>8.1} ms (wall {wall:.1} ms, {} dets)",
                out.host_ms,
                out.detections.len()
            );
        }
        total / scenes as f64
    };
    println!("\npipeline PointSplit int8, {scenes} scenes of {n} points:");
    let seq_ms = run_ms(&seq_pipe, "seq");
    let par_ms = run_ms(&par_pipe, "par");
    let speedup = seq_ms / par_ms.max(1e-9);

    let mut t = Table::new(&["pipeline", "host_ms seq", "host_ms par", "speedup"]);
    t.row(vec!["pointsplit int8".into(), f1(seq_ms), f1(par_ms), f2(speedup)]);
    t.print("pipeline host_ms: sequential vs DAG-parallel");
    println!(
        "\nacceptance: >= 1.5x on a >= 4-core runner -> {}",
        if speedup >= 1.5 { "PASS" } else { "below (small host or smoke settings)" }
    );

    let payload = Json::obj(vec![
        ("bench", Json::Str("pointops_parallel".to_string())),
        ("n", Json::Num(n as f64)),
        ("m", Json::Num(m as f64)),
        ("threads", Json::Num(threads as f64)),
        (
            "ops",
            Json::obj(vec![
                ("fps", op_row(&fps_scalar, &fps_seq, &fps_par)),
                ("biased_fps", op_row(&bfps_scalar, &bfps_seq, &bfps_par)),
                ("ball_query", op_row(&bq_scalar, &bq_seq, &bq_par)),
                ("three_nn", op_row(&in_scalar, &in_grid, &in_par)),
                ("three_nn_brute_ms", Json::Num(in_brute.mean_us / 1e3)),
            ]),
        ),
        (
            "pipeline",
            Json::obj(vec![
                ("scenes", Json::Num(scenes as f64)),
                ("seq_host_ms", Json::Num(seq_ms)),
                ("par_host_ms", Json::Num(par_ms)),
                ("speedup", Json::Num(speedup)),
            ]),
        ),
    ]);
    update_bench_json("BENCH_hotpath.json", "pointops_parallel", payload);

    if std::env::var("POINTSPLIT_BENCH_ASSERT").is_ok() {
        assert!(speedup >= 1.5, "pipeline parallel speedup {speedup:.2} < 1.5x");
    }
}
