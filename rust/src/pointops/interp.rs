//! Feature propagation: inverse-distance-weighted 3-NN interpolation
//! (mirror of sampling.three_nn_interpolate).

use crate::util::tensor::Tensor;

/// Interpolate `src_feats` (Ns, C) at `dst_xyz` from `src_xyz` -> (Nd, C).
pub fn three_nn_interpolate(
    dst_xyz: &[[f32; 3]],
    src_xyz: &[[f32; 3]],
    src_feats: &Tensor,
) -> Tensor {
    assert_eq!(src_xyz.len(), src_feats.rows());
    let c = src_feats.row_len();
    let mut out = Vec::with_capacity(dst_xyz.len() * c);
    for d in dst_xyz {
        // 3 nearest sources
        let mut best = [(f32::INFINITY, 0usize); 3];
        for (j, s) in src_xyz.iter().enumerate() {
            let dx = d[0] - s[0];
            let dy = d[1] - s[1];
            let dz = d[2] - s[2];
            let d2 = dx * dx + dy * dy + dz * dz;
            if d2 < best[2].0 {
                best[2] = (d2, j);
                if best[2].0 < best[1].0 {
                    best.swap(1, 2);
                }
                if best[1].0 < best[0].0 {
                    best.swap(0, 1);
                }
            }
        }
        let w: Vec<f32> = best.iter().map(|&(d2, _)| 1.0 / d2.max(1e-8)).collect();
        let wsum: f32 = w.iter().sum();
        let start = out.len();
        out.resize(start + c, 0.0);
        for (wi, &(_, j)) in w.iter().zip(best.iter()) {
            let row = src_feats.row(j);
            let wn = wi / wsum;
            for (o, v) in out[start..].iter_mut().zip(row.iter()) {
                *o += wn * v;
            }
        }
    }
    Tensor::new(vec![dst_xyz.len(), c], out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_at_source_points() {
        let src = vec![[0.0, 0.0, 0.0], [1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [1.0, 1.0, 0.0]];
        let feats = Tensor::new(vec![4, 2], vec![1., 10., 2., 20., 3., 30., 4., 40.]);
        let out = three_nn_interpolate(&src, &src, &feats);
        // at a source point the nearest neighbor has d2~0 -> dominates
        assert!((out.row(2)[0] - 3.0).abs() < 1e-3);
        assert!((out.row(2)[1] - 30.0).abs() < 1e-2);
    }

    #[test]
    fn interpolation_is_convex_combination() {
        let src = vec![[0.0, 0.0, 0.0], [2.0, 0.0, 0.0], [0.0, 2.0, 0.0]];
        let feats = Tensor::new(vec![3, 1], vec![0.0, 6.0, 12.0]);
        let out = three_nn_interpolate(&[[0.5, 0.5, 0.0]], &src, &feats);
        let v = out.data[0];
        assert!(v > 0.0 && v < 12.0);
    }
}
