//! §Perf: wall-clock micro-benchmarks of the L3 hot path on this host.
//!
//! These numbers feed EXPERIMENTS.md §Perf (before/after optimization log)
//! and are persisted to `BENCH_hotpath.json` (section `perf_hotpath`, merged
//! alongside `pointops_parallel`) so the scalar → SIMD → parallel trajectory
//! of every kernel is diffable across runs. Covered: FPS, biased FPS, ball
//! query, grouping, 3-NN interpolation, scene generation, full functional
//! pipeline, and PJRT executable dispatch.
//!
//! Knobs:
//!   POINTSPLIT_BENCH_POINTS   kernel-trajectory cloud size (default 8192)
//!   POINTSPLIT_BENCH_SCENES   pipeline iterations          (default 8, CI: 1)

mod common;

use pointsplit::bench::{bench_fn, f2, update_bench_json, BenchResult, Table};
use pointsplit::coordinator::{DetectorConfig, ScenePipeline, Schedule, Variant};
use pointsplit::data::{generate_scene, SYNRGBD};
use pointsplit::pointops;
use pointsplit::sim::DeviceKind;
use pointsplit::util::json::Json;
use pointsplit::util::rng::Rng;
use pointsplit::util::tensor::Tensor;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// One kernel's scalar → SIMD → parallel trajectory as a JSON row.
fn traj(scalar: &BenchResult, simd: &BenchResult, par: &BenchResult) -> Json {
    Json::obj(vec![
        ("scalar_ms", Json::Num(scalar.mean_us / 1e3)),
        ("simd_ms", Json::Num(simd.mean_us / 1e3)),
        ("par_ms", Json::Num(par.mean_us / 1e3)),
        ("speedup_simd", Json::Num(scalar.mean_us / simd.mean_us.max(1e-9))),
        ("speedup_par", Json::Num(scalar.mean_us / par.mean_us.max(1e-9))),
    ])
}

fn main() {
    let rt = common::open_runtime();
    let scene = generate_scene(3, &SYNRGBD);
    let fg: Vec<f32> =
        scene.point_obj.iter().map(|&o| if o >= 0 { 1.0 } else { 0.0 }).collect();
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let scenes = common::scene_budget(8);

    println!("=== §Perf hot-path micro-benchmarks (host wall-clock) ===\n");
    bench_fn("fps 2048->256", 3, 30, || {
        std::hint::black_box(pointops::fps(&scene.points, 256));
    })
    .print();
    bench_fn("biased_fps 2048->256 (w0=2)", 3, 30, || {
        std::hint::black_box(pointops::biased_fps(&scene.points, 256, &fg, 2.0));
    })
    .print();
    let centers = pointops::fps(&scene.points, 256);
    bench_fn("ball_query 2048x256 k=32", 3, 30, || {
        std::hint::black_box(pointops::ball_query(&scene.points, &centers, 0.3, 32));
    })
    .print();
    let groups = pointops::ball_query(&scene.points, &centers, 0.3, 32);
    let feats = pointops::build_features(&scene, None);
    bench_fn("group_features 256x32", 3, 50, || {
        std::hint::black_box(pointops::group_features(&scene.points, Some(&feats), &centers, &groups));
    })
    .print();
    let coarse: Vec<[f32; 3]> = centers.iter().map(|&i| scene.points[i]).collect();
    let cfeats = Tensor::zeros(vec![256, 128]);
    bench_fn("three_nn_interp 2048<-256 c=128", 3, 20, || {
        std::hint::black_box(pointops::three_nn_interpolate(&scene.points, &coarse, &cfeats));
    })
    .print();
    bench_fn("scene generation (synrgbd)", 2, 20, || {
        std::hint::black_box(generate_scene(11, &SYNRGBD));
    })
    .print();

    // ------------------------------------- scalar -> SIMD -> par trajectory
    // the acceptance metric of the SoA/SIMD rewrite: the lane kernels must
    // beat the scalar oracles (bit-identical results, pinned by tests) on a
    // larger cloud where the distance loops dominate
    let n = env_usize("POINTSPLIT_BENCH_POINTS", 8192);
    let m = (n / 8).clamp(1, 1024);
    let mut rng = Rng::new(7);
    let cloud: Vec<[f32; 3]> = (0..n)
        .map(|_| [rng.f32() * 8.0, rng.f32() * 8.0, rng.f32() * 2.5])
        .collect();
    println!("\nkernel trajectory (n={n}, m={m}, {threads} threads):");
    let fps_scalar = bench_fn(&format!("fps {n}->{m} scalar"), 1, 10, || {
        std::hint::black_box(pointops::fps_scalar(&cloud, m, None, 1.0, 0));
    });
    fps_scalar.print();
    let fps_simd = bench_fn(&format!("fps {n}->{m} simd"), 1, 10, || {
        std::hint::black_box(pointops::fps(&cloud, m));
    });
    fps_simd.print();
    let fps_par = bench_fn(&format!("fps {n}->{m} simd par x{threads}"), 1, 10, || {
        std::hint::black_box(pointops::fps_par(&cloud, m, threads));
    });
    fps_par.print();

    let kcenters = pointops::fps(&cloud, m);
    let bq_scalar = bench_fn(&format!("ball_query {n}x{m} k=32 scalar"), 1, 10, || {
        std::hint::black_box(pointops::ball_query_scalar(&cloud, &kcenters, 0.4, 32));
    });
    bq_scalar.print();
    let bq_simd = bench_fn(&format!("ball_query {n}x{m} k=32 simd"), 1, 10, || {
        std::hint::black_box(pointops::ball_query(&cloud, &kcenters, 0.4, 32));
    });
    bq_simd.print();
    let bq_par = bench_fn(&format!("ball_query {n}x{m} k=32 simd par x{threads}"), 1, 10, || {
        std::hint::black_box(pointops::ball_query_par(&cloud, &kcenters, 0.4, 32, threads));
    });
    bq_par.print();

    // c=16 keeps the bench on the knn search, not feature accumulation
    let src: Vec<[f32; 3]> = kcenters.iter().map(|&i| cloud[i]).collect();
    let sfeats = Tensor::zeros(vec![src.len(), 16]);
    let nn_scalar = bench_fn(&format!("three_nn {n}<-{m} c=16 scalar"), 1, 10, || {
        std::hint::black_box(pointops::three_nn_interpolate_scalar(&cloud, &src, &sfeats));
    });
    nn_scalar.print();
    let nn_simd = bench_fn(&format!("three_nn {n}<-{m} c=16 simd"), 1, 10, || {
        std::hint::black_box(pointops::three_nn_interpolate(&cloud, &src, &sfeats));
    });
    nn_simd.print();
    let nn_par = bench_fn(&format!("three_nn {n}<-{m} c=16 simd par x{threads}"), 1, 10, || {
        std::hint::black_box(pointops::three_nn_interpolate_par(&cloud, &src, &sfeats, threads));
    });
    nn_par.print();

    let mut t = Table::new(&["kernel", "scalar ms", "simd ms", "par ms", "simd speedup"]);
    let rows = [
        ("fps", &fps_scalar, &fps_simd, &fps_par),
        ("ball_query", &bq_scalar, &bq_simd, &bq_par),
        ("three_nn", &nn_scalar, &nn_simd, &nn_par),
    ];
    let mut wins = 0;
    for (name, sc, si, pa) in rows {
        let speedup = sc.mean_us / si.mean_us.max(1e-9);
        if speedup >= 1.5 {
            wins += 1;
        }
        t.row(vec![
            name.to_string(),
            f2(sc.mean_us / 1e3),
            f2(si.mean_us / 1e3),
            f2(pa.mean_us / 1e3),
            f2(speedup),
        ]);
    }
    t.print("kernel trajectory: scalar oracle vs SIMD lanes");
    println!(
        "\nacceptance: >= 1.5x SIMD speedup on >= 2 of 3 kernels -> {}",
        if wins >= 2 { "PASS" } else { "below (smoke settings or tiny cloud)" }
    );

    // PJRT dispatch cost: the smallest artifact round-trip
    let seeds = Tensor::zeros(vec![rt.manifest.num_seeds, rt.manifest.seed_feat]);
    bench_fn("pjrt dispatch (vote fp32)", 3, 30, || {
        std::hint::black_box(rt.run("synrgbd_pointsplit_vote_fp32", &[&seeds]).unwrap());
    })
    .print();

    // full functional pipelines
    let mut pipe_rows = Vec::new();
    for (name, variant, int8) in [
        ("pipeline votenet fp32", Variant::VoteNet, false),
        ("pipeline pointsplit fp32", Variant::PointSplit, false),
        ("pipeline pointsplit int8", Variant::PointSplit, true),
    ] {
        let cfg = DetectorConfig::new(
            "synrgbd",
            variant,
            int8,
            Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
        );
        let pipe = ScenePipeline::new(&rt, cfg);
        let r = bench_fn(name, 1, scenes, || {
            std::hint::black_box(pipe.run(&scene, 3).unwrap());
        });
        r.print();
        pipe_rows.push((name, Json::Num(r.mean_us / 1e3)));
    }

    let payload = Json::obj(vec![
        ("bench", Json::Str("perf_hotpath".to_string())),
        ("n", Json::Num(n as f64)),
        ("m", Json::Num(m as f64)),
        ("threads", Json::Num(threads as f64)),
        (
            "kernels",
            Json::obj(vec![
                ("fps", traj(&fps_scalar, &fps_simd, &fps_par)),
                ("ball_query", traj(&bq_scalar, &bq_simd, &bq_par)),
                ("three_nn", traj(&nn_scalar, &nn_simd, &nn_par)),
            ]),
        ),
        ("simd_wins", Json::Num(wins as f64)),
        ("pass", Json::Bool(wins >= 2)),
        ("pipelines_ms", Json::obj(pipe_rows)),
    ]);
    update_bench_json("BENCH_hotpath.json", "perf_hotpath", payload);
}
