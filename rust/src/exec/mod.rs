//! Scoped-thread DAG executor for the per-scene pipeline (std only).
//!
//! The paper's pillar (2) is *parallelized 3D feature extraction*: the
//! SA-normal and SA-bias half-pipelines run concurrently on GPU and EdgeTPU.
//! This module gives the **host** execution the same shape. A pipeline is a
//! list of [`StageDecl`]s — each stage declared exactly once as
//! (name, device, workload, deps, compute closure) — and the executor runs
//! the closures respecting the dependency edges, so independent stages (the
//! two SA chains of PointSplit, the two halves of RandomSplit) overlap on
//! host threads instead of running back-to-back.
//!
//! The same declarations feed [`crate::sim::ScheduleSim`] (via the embedded
//! [`StageSpec`]s), which structurally rules out the class of drift bugs
//! where the simulated DAG and the functional execution disagree about
//! dependencies.
//!
//! Two lanes:
//! - [`Compute::Pool`] — pure point-op work; may run on any worker thread.
//! - [`Compute::Host`] — work that must stay on the invoking thread (PJRT
//!   executable handles are `Rc`-based and `!Send` with the real `xla`
//!   backend), i.e. every NN stage.
//!
//! Determinism: closures communicate only through [`Slot`]s they own, every
//! slot has exactly one producer, and a consumer only runs after all its
//! producers completed — so the parallel execution computes bit-identical
//! values to the sequential one regardless of thread interleaving
//! (property-tested in `rust/tests/parallelism.rs`).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, Result};

use crate::sim::StageSpec;

/// Single-producer, multi-consumer value cell connecting stage closures.
///
/// The executor guarantees a consumer's closure only runs after its
/// producers completed, so reads never block — a missing value is a wiring
/// bug and panics with the slot's debug name.
pub struct Slot<T> {
    inner: Arc<Mutex<Option<T>>>,
    name: &'static str,
}

impl<T> Clone for Slot<T> {
    fn clone(&self) -> Self {
        Slot { inner: self.inner.clone(), name: self.name }
    }
}

impl<T> Slot<T> {
    pub fn new(name: &'static str) -> Slot<T> {
        Slot { inner: Arc::new(Mutex::new(None)), name }
    }

    /// Publish the value (producer side).
    pub fn set(&self, v: T) {
        *self.inner.lock().unwrap() = Some(v);
    }

    /// Move the value out (single/last consumer).
    pub fn take(&self) -> T {
        self.inner
            .lock()
            .unwrap()
            .take()
            .unwrap_or_else(|| panic!("slot '{}' read before its producer ran", self.name))
    }

    /// Borrow the value through a closure (shared consumers).
    pub fn with<R>(&self, f: impl FnOnce(&T) -> R) -> R {
        let guard = self.inner.lock().unwrap();
        let v = guard
            .as_ref()
            .unwrap_or_else(|| panic!("slot '{}' read before its producer ran", self.name));
        f(v)
    }
}

impl<T: Clone> Slot<T> {
    /// Clone the value out (shared consumers of cheap data).
    pub fn cloned(&self) -> T {
        self.with(|v| v.clone())
    }
}

/// Host execution policy of a pipeline run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostExec {
    /// Run every stage closure on the calling thread in submission order.
    Sequential,
    /// DAG-parallel: pool stages spread over `threads` total threads
    /// (including the calling thread, which also owns the host lane).
    Parallel { threads: usize },
}

impl HostExec {
    /// Default policy: parallel over the machine's cores (capped at 8),
    /// overridable with `POINTSPLIT_HOST_THREADS` (1 forces sequential).
    pub fn auto() -> HostExec {
        let t = std::env::var("POINTSPLIT_HOST_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(8)
            });
        if t <= 1 {
            HostExec::Sequential
        } else {
            HostExec::Parallel { threads: t }
        }
    }

    /// Total thread budget (1 = sequential).
    pub fn threads(self) -> usize {
        match self {
            HostExec::Sequential => 1,
            HostExec::Parallel { threads } => threads.max(1),
        }
    }
}

/// A stage's functional work.
pub enum Compute<'s> {
    /// Pure host computation; may run on any pool thread.
    Pool(Box<dyn FnOnce() -> Result<()> + Send + 's>),
    /// Must run on the invoking thread (e.g. touches PJRT handles).
    Host(Box<dyn FnOnce() -> Result<()> + 's>),
}

/// One pipeline stage: the simulator spec plus the host closure computing it.
pub struct StageDecl<'s> {
    /// What the calibrated device model simulates — name, device, the
    /// stage's numeric precision (the QuantScheme property pricing it),
    /// workload, and the *timeline* dependencies.
    pub spec: StageSpec,
    /// Host-ordering dependencies beyond `spec.deps` (data produced by a
    /// stage the simulated timeline does not wait for, e.g. painted features
    /// gathered during an NN stage's transfer window).
    pub extra_deps: Vec<usize>,
    pub compute: Compute<'s>,
}

/// Dependency-respecting executor over a list of [`StageDecl`]s.
pub struct DagExecutor {
    mode: HostExec,
}

/// Shared scheduler state for the parallel path.
struct SchedState<'s> {
    pool_jobs: Vec<Option<Box<dyn FnOnce() -> Result<()> + Send + 's>>>,
    ready_pool: VecDeque<usize>,
    ready_host: VecDeque<usize>,
    /// stages unlocked by each stage's completion
    dependents: Vec<Vec<usize>>,
    indegree: Vec<usize>,
    remaining: usize,
    failed: Option<anyhow::Error>,
}

struct Shared<'s> {
    state: Mutex<SchedState<'s>>,
    cv: Condvar,
    is_host: Vec<bool>,
}

impl DagExecutor {
    pub fn new(mode: HostExec) -> DagExecutor {
        DagExecutor { mode }
    }

    /// Execute all stage closures respecting `spec.deps ∪ extra_deps`;
    /// returns the [`StageSpec`]s for the schedule simulator. Fails fast on
    /// the first stage error (remaining stages are skipped).
    pub fn run(&self, decls: Vec<StageDecl<'_>>) -> Result<Vec<StageSpec>> {
        let n = decls.len();
        let mut specs = Vec::with_capacity(n);
        let mut deps: Vec<Vec<usize>> = Vec::with_capacity(n);
        let mut computes = Vec::with_capacity(n);
        for (i, d) in decls.into_iter().enumerate() {
            let mut all: Vec<usize> = d.spec.deps.clone();
            all.extend_from_slice(&d.extra_deps);
            all.sort_unstable();
            all.dedup();
            if all.iter().any(|&p| p >= i) {
                return Err(anyhow!(
                    "stage {i} ('{}') depends on itself or a later stage",
                    d.spec.name
                ));
            }
            deps.push(all);
            specs.push(d.spec);
            computes.push(d.compute);
        }
        if self.mode.threads() <= 1 {
            // submission order is a topological order (deps point backwards)
            for c in computes {
                match c {
                    Compute::Pool(f) => f()?,
                    Compute::Host(f) => f()?,
                }
            }
            return Ok(specs);
        }
        self.run_parallel(&deps, computes)?;
        Ok(specs)
    }

    fn run_parallel<'s>(&self, deps: &[Vec<usize>], computes: Vec<Compute<'s>>) -> Result<()> {
        let n = computes.len();
        let mut is_host = vec![false; n];
        let mut pool_jobs: Vec<Option<Box<dyn FnOnce() -> Result<()> + Send + 's>>> =
            (0..n).map(|_| None).collect();
        let mut host_jobs: Vec<Option<Box<dyn FnOnce() -> Result<()> + 's>>> =
            (0..n).map(|_| None).collect();
        for (i, c) in computes.into_iter().enumerate() {
            match c {
                Compute::Pool(f) => pool_jobs[i] = Some(f),
                Compute::Host(f) => {
                    is_host[i] = true;
                    host_jobs[i] = Some(f);
                }
            }
        }
        let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut indegree = vec![0usize; n];
        let mut ready_pool = VecDeque::new();
        let mut ready_host = VecDeque::new();
        for (i, ds) in deps.iter().enumerate() {
            indegree[i] = ds.len();
            for &p in ds {
                dependents[p].push(i);
            }
            if ds.is_empty() {
                if is_host[i] {
                    ready_host.push_back(i);
                } else {
                    ready_pool.push_back(i);
                }
            }
        }
        let shared = Shared {
            state: Mutex::new(SchedState {
                pool_jobs,
                ready_pool,
                ready_host,
                dependents,
                indegree,
                remaining: n,
                failed: None,
            }),
            cv: Condvar::new(),
            is_host,
        };
        let workers = self.mode.threads().saturating_sub(1).min(n.max(1));
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let job = {
                        let mut st = shared.state.lock().unwrap();
                        loop {
                            if st.remaining == 0 || st.failed.is_some() {
                                return;
                            }
                            if let Some(i) = st.ready_pool.pop_front() {
                                let f = st.pool_jobs[i].take().expect("pool job present");
                                break (i, f);
                            }
                            st = shared.cv.wait(st).unwrap();
                        }
                    };
                    let res = (job.1)();
                    finish(&shared, job.0, res);
                });
            }
            // The calling thread owns the host lane and helps with pool
            // work when the host lane is idle (work-conserving).
            loop {
                let job = {
                    let mut st = shared.state.lock().unwrap();
                    loop {
                        if st.remaining == 0 || st.failed.is_some() {
                            shared.cv.notify_all();
                            return;
                        }
                        if let Some(i) = st.ready_host.pop_front() {
                            break HostJob::Host(i);
                        }
                        if let Some(i) = st.ready_pool.pop_front() {
                            let f = st.pool_jobs[i].take().expect("pool job present");
                            break HostJob::Pool(i, f);
                        }
                        st = shared.cv.wait(st).unwrap();
                    }
                };
                match job {
                    HostJob::Host(i) => {
                        let f = host_jobs[i].take().expect("host job present");
                        let res = f();
                        finish(&shared, i, res);
                    }
                    HostJob::Pool(i, f) => {
                        let res = f();
                        finish(&shared, i, res);
                    }
                }
            }
        });
        let mut st = shared.state.lock().unwrap();
        match st.failed.take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

enum HostJob<'s> {
    Host(usize),
    Pool(usize, Box<dyn FnOnce() -> Result<()> + Send + 's>),
}

fn finish(shared: &Shared<'_>, i: usize, res: Result<()>) {
    let mut st = shared.state.lock().unwrap();
    st.remaining -= 1;
    match res {
        Ok(()) => {
            let unlocked = std::mem::take(&mut st.dependents[i]);
            for j in unlocked {
                st.indegree[j] -= 1;
                if st.indegree[j] == 0 {
                    if shared.is_host[j] {
                        st.ready_host.push_back(j);
                    } else {
                        st.ready_pool.push_back(j);
                    }
                }
            }
        }
        Err(e) => {
            if st.failed.is_none() {
                st.failed = Some(e);
            }
        }
    }
    shared.cv.notify_all();
}

/// Contiguous `(start, end)` index ranges for row-tile parallelism, with
/// the same budget clamping the point-op kernels use: `threads <= 1`, tiny
/// inputs (fewer than `min_per_tile` rows per would-be tile), or `n == 0`
/// collapse to at most one tile, so callers fall through to their
/// sequential path and the results stay identical for any thread count.
pub fn row_tiles(n: usize, threads: usize, min_per_tile: usize) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let by_size = if min_per_tile == 0 { threads } else { n / min_per_tile };
    let nt = threads.min(by_size).min(n).max(1);
    let chunk = n.div_ceil(nt);
    (0..nt)
        .map(|i| (i * chunk, ((i + 1) * chunk).min(n)))
        .filter(|(a, b)| a < b)
        .collect()
}

/// Deterministic parallel map: applies `f` to every item on up to `threads`
/// scoped threads, preserving input order. Falls back to a plain loop for
/// tiny inputs or `threads <= 1`. `f` receives `(index, item)`.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if threads <= 1 || n < 2 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let nt = threads.min(n);
    let chunk = n.div_ceil(nt);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        let f = &f;
        for (ci, (ochunk, ichunk)) in out.chunks_mut(chunk).zip(items.chunks(chunk)).enumerate() {
            scope.spawn(move || {
                for (j, (o, it)) in ochunk.iter_mut().zip(ichunk.iter()).enumerate() {
                    *o = Some(f(ci * chunk + j, it));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("par_map filled every slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn decl<'s>(name: &str, deps: Vec<usize>, compute: Compute<'s>) -> StageDecl<'s> {
        use crate::sim::{DeviceKind, Precision, Workload, WorkloadKind};
        StageDecl {
            spec: StageSpec {
                name: name.to_string(),
                device: DeviceKind::Cpu,
                precision: Precision::Fp32,
                workload: Workload {
                    kind: WorkloadKind::PointOp,
                    flops: 1,
                    mem_bytes: 0,
                    wire_bytes: 0,
                },
                deps,
            },
            extra_deps: Vec::new(),
            compute,
        }
    }

    fn modes() -> [HostExec; 3] {
        [
            HostExec::Sequential,
            HostExec::Parallel { threads: 2 },
            HostExec::Parallel { threads: 8 },
        ]
    }

    #[test]
    fn diamond_dag_respects_order() {
        for mode in modes() {
            let log = Arc::new(Mutex::new(Vec::new()));
            let push = |tag: &'static str| {
                let log = log.clone();
                Compute::Pool(Box::new(move || {
                    log.lock().unwrap().push(tag);
                    Ok(())
                }))
            };
            let decls = vec![
                decl("a", vec![], push("a")),
                decl("b", vec![0], push("b")),
                decl("c", vec![0], push("c")),
                decl("d", vec![1, 2], push("d")),
            ];
            let specs = DagExecutor::new(mode).run(decls).unwrap();
            assert_eq!(specs.len(), 4);
            let order = log.lock().unwrap().clone();
            assert_eq!(order.len(), 4);
            assert_eq!(order[0], "a");
            assert_eq!(order[3], "d");
        }
    }

    #[test]
    fn host_stages_run_on_calling_thread() {
        let main_id = std::thread::current().id();
        for mode in modes() {
            let seen = Arc::new(Mutex::new(Vec::new()));
            let decls = (0..6)
                .map(|i| {
                    let seen = seen.clone();
                    decl(
                        "h",
                        if i == 0 { vec![] } else { vec![i - 1] },
                        Compute::Host(Box::new(move || {
                            seen.lock().unwrap().push(std::thread::current().id());
                            Ok(())
                        })),
                    )
                })
                .collect();
            DagExecutor::new(mode).run(decls).unwrap();
            assert!(
                seen.lock().unwrap().iter().all(|&id| id == main_id),
                "host-lane stage escaped the calling thread ({mode:?})"
            );
        }
    }

    #[test]
    fn independent_pool_stages_overlap() {
        // two stages that each wait for the other to start can only finish
        // if they truly run concurrently
        let gate = Arc::new((Mutex::new(0usize), Condvar::new()));
        let enter = |gate: Arc<(Mutex<usize>, Condvar)>| {
            Compute::Pool(Box::new(move || {
                let (m, cv) = &*gate;
                let mut count = m.lock().unwrap();
                *count += 1;
                cv.notify_all();
                let deadline = std::time::Duration::from_secs(10);
                while *count < 2 {
                    let (c, timeout) = cv.wait_timeout(count, deadline).unwrap();
                    count = c;
                    if timeout.timed_out() {
                        return Err(anyhow!("peer stage never started: no overlap"));
                    }
                }
                Ok(())
            }))
        };
        let decls = vec![
            decl("x", vec![], enter(gate.clone())),
            decl("y", vec![], enter(gate.clone())),
        ];
        DagExecutor::new(HostExec::Parallel { threads: 4 }).run(decls).unwrap();
    }

    #[test]
    fn error_propagates_and_skips_dependents() {
        for mode in modes() {
            let ran = Arc::new(AtomicUsize::new(0));
            let ran2 = ran.clone();
            let decls = vec![
                decl("bad", vec![], Compute::Pool(Box::new(|| Err(anyhow!("boom"))))),
                decl(
                    "after",
                    vec![0],
                    Compute::Pool(Box::new(move || {
                        ran2.fetch_add(1, Ordering::SeqCst);
                        Ok(())
                    })),
                ),
            ];
            let err = DagExecutor::new(mode).run(decls).unwrap_err();
            assert!(format!("{err:#}").contains("boom"));
            assert_eq!(ran.load(Ordering::SeqCst), 0, "dependent of failed stage ran");
        }
    }

    #[test]
    fn forward_dep_rejected() {
        let decls = vec![
            decl("a", vec![1], Compute::Pool(Box::new(|| Ok(())))),
            decl("b", vec![], Compute::Pool(Box::new(|| Ok(())))),
        ];
        assert!(DagExecutor::new(HostExec::Sequential).run(decls).is_err());
    }

    #[test]
    fn slots_move_values_between_stages() {
        for mode in modes() {
            let a: Slot<Vec<u32>> = Slot::new("a");
            let b: Slot<u32> = Slot::new("b");
            let (a1, a2, b1) = (a.clone(), a.clone(), b.clone());
            let decls = vec![
                decl(
                    "produce",
                    vec![],
                    Compute::Pool(Box::new(move || {
                        a1.set(vec![1, 2, 3]);
                        Ok(())
                    })),
                ),
                decl(
                    "consume",
                    vec![0],
                    Compute::Host(Box::new(move || {
                        b1.set(a2.with(|v| v.iter().sum()));
                        Ok(())
                    })),
                ),
            ];
            DagExecutor::new(mode).run(decls).unwrap();
            assert_eq!(b.take(), 6);
        }
    }

    #[test]
    fn par_map_matches_sequential_in_order() {
        let items: Vec<u64> = (0..1000).collect();
        let seq: Vec<u64> = items.iter().map(|&x| x * x + 1).collect();
        for threads in [1, 2, 3, 8] {
            assert_eq!(par_map(&items, threads, |_, &x| x * x + 1), seq);
        }
        let empty: Vec<u64> = Vec::new();
        assert!(par_map(&empty, 4, |_, &x: &u64| x).is_empty());
    }
}
