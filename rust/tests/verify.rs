//! Acceptance suite for the static verifier (`pointsplit::verify`).
//!
//! Two halves:
//!   1. Metamorphic properties (custom harness in `util::prop`): if a built
//!      graph verifies clean, then every pass output derived from it —
//!      `batch_fold`, `quant_rewrite`, the SLO degrade rewrite, and the
//!      schedule the placement search ranks best — verifies clean too.
//!      Random configurations cover corners the shipped-config sweep in
//!      `pointsplit verify` never enumerates.
//!   2. A seeded corpus of known-bad graphs, each pinned to the rule id
//!      that must catch it via `Report::fired` — so a rule renumbering or
//!      an accidentally-broadened sibling check cannot silently absorb a
//!      case. The E001 entry re-introduces the PR 2 sa4 merge bug.

use pointsplit::cluster::{config_mix, ClusterSpec};
use pointsplit::coordinator::{DetectorConfig, Schedule, Variant};
use pointsplit::graph::{place, StageClass, StageGraph};
use pointsplit::quant::{Granularity, QuantScheme};
use pointsplit::runtime::Manifest;
use pointsplit::serving::{slo, BatchPolicy, ServicePlanner};
use pointsplit::sim::{DeviceKind, ScheduleSim, WorkloadKind};
use pointsplit::util::prop::{check, PropConfig};
use pointsplit::util::rng::Rng;
use pointsplit::verify;

const VARIANTS: [Variant; 4] =
    [Variant::VoteNet, Variant::PointPainting, Variant::RandomSplit, Variant::PointSplit];
const ALL_DEVICES: [DeviceKind; 3] = [DeviceKind::Cpu, DeviceKind::Gpu, DeviceKind::EdgeTpu];

fn pipelined() -> Schedule {
    Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu }
}

/// The shipped flagship config, built: the corpus mutates copies of this.
fn split_graph() -> (Manifest, StageGraph) {
    let m = Manifest::synthetic();
    let cfg = DetectorConfig::new("synrgbd", Variant::PointSplit, true, pipelined());
    let g = StageGraph::build(&m, &cfg, 2048, false).expect("shipped config must build");
    (m, g)
}

/// A random but *valid* configuration: any dataset/variant/precision, any
/// schedule whose point device can actually run point ops (the EdgeTPU
/// cannot — solo-EdgeTPU is a legitimately infeasible placement, not a
/// metamorphic counterexample).
fn random_config(rng: &mut Rng) -> DetectorConfig {
    let point = [DeviceKind::Cpu, DeviceKind::Gpu][rng.below(2)];
    let nn = ALL_DEVICES[rng.below(3)];
    let schedule = match rng.below(3) {
        0 => Schedule::SingleDevice(point),
        1 => Schedule::Sequential { point_dev: point, nn_dev: nn },
        _ => Schedule::Pipelined { point_dev: point, nn_dev: nn },
    };
    let ds = ["synrgbd", "synscan"][rng.below(2)];
    let mut cfg = DetectorConfig::new(ds, VARIANTS[rng.below(4)], rng.below(2) == 0, schedule);
    cfg.w0 = [1.0, 2.0, 4.0][rng.below(3)];
    cfg.bias_layers = rng.below(4);
    cfg
}

// ----------------------------------------------------- metamorphic properties

/// verify(g) clean ⇒ verify of every pass output clean: batch_fold is
/// exactly k-scalable, quant_rewrite and the SLO degrade rewrite produce
/// graphs that verify, and the placement search's best-ranked schedule
/// rebuilds into a graph that passes the full rule set.
#[test]
fn prop_passes_preserve_verification() {
    let m = Manifest::synthetic();
    let sim = ScheduleSim::new();
    check("verify-metamorphic", PropConfig { cases: 48, seed: 0x5EED }, |rng, _size| {
        let cfg = random_config(rng);
        let num_points = [1024, 2048][rng.below(2)];
        let skip_seg = cfg.variant.painted() && rng.below(2) == 0;
        let g = StageGraph::build(&m, &cfg, num_points, skip_seg)
            .map_err(|e| format!("build: {e:#}"))?;
        let base = verify::verify_graph(&m, &g);
        if base.has_errors() {
            return Err(format!("base graph must verify clean:\n{base}"));
        }

        let k = 1 + rng.below(4);
        let fold = verify::check_fold(&g.specs(), &g.batch_fold(k), k);
        if fold.has_errors() {
            return Err(format!("batch_fold({k}) broke k-scalability:\n{fold}"));
        }

        let scheme = match rng.below(4) {
            0 => QuantScheme::fp32(),
            1 => QuantScheme::int8(Granularity::Layer),
            2 => QuantScheme::int8(Granularity::Role),
            _ => cfg.scheme.degraded(),
        };
        let rw = g.quant_rewrite(&m, scheme).map_err(|e| format!("quant_rewrite: {e:#}"))?;
        let r = verify::verify_graph(&m, &rw);
        if r.has_errors() {
            return Err(format!("quant_rewrite output failed verification:\n{r}"));
        }

        let fast = slo::degraded_graph(&m, &g).map_err(|e| format!("degraded_graph: {e:#}"))?;
        let r = verify::verify_graph(&m, &fast);
        if r.has_errors() {
            return Err(format!("degraded_graph output failed verification:\n{r}"));
        }

        let s = place::search(&m, &cfg, num_points, 1, &ALL_DEVICES, place::Objective::Latency)
            .map_err(|e| format!("place::search: {e:#}"))?;
        let best = s.best().ok_or_else(|| "search ranked no candidates".to_string())?;
        let mut placed = cfg.clone();
        placed.schedule = best.schedule;
        let g2 = StageGraph::build(&m, &placed, num_points, skip_seg)
            .map_err(|e| format!("build(best placement): {e:#}"))?;
        let r = verify::verify_all(&sim, &m, &g2, 1);
        if r.has_errors() {
            return Err(format!("best-ranked placement failed verification:\n{r}"));
        }
        Ok(())
    });
}

/// Exhaustive version of the placement clause: *every* candidate the search
/// ranks (not just the best) rebuilds into a clean graph. The search rejects
/// through the verifier's own shared P001/S001 rule, so a ranked-but-broken
/// schedule would mean the two code paths disagree.
#[test]
fn placement_candidates_verify_clean() {
    let m = Manifest::synthetic();
    let sim = ScheduleSim::new();
    for int8 in [false, true] {
        let cfg = DetectorConfig::new("synrgbd", Variant::PointSplit, int8, pipelined());
        let s = place::search(&m, &cfg, 2048, 1, &ALL_DEVICES, place::Objective::Latency)
            .expect("search over the full device set succeeds");
        assert!(s.best().is_some(), "search must rank at least one candidate");
        for c in &s.candidates {
            let mut ranked = cfg.clone();
            ranked.schedule = c.schedule;
            let g = StageGraph::build(&m, &ranked, 2048, false).expect("candidate builds");
            let rep = verify::verify_all(&sim, &m, &g, 1);
            assert!(!rep.has_errors(), "candidate {:?} fails verification:\n{rep}", c.schedule);
        }
    }
}

/// The acceptance sweep as a test: every shipped configuration verifies
/// with zero errors (warnings like P003 degenerate-placement are allowed).
#[test]
fn shipped_configs_verify_clean() {
    let m = Manifest::synthetic();
    let sim = ScheduleSim::new();
    for ds in ["synrgbd", "synscan"] {
        for variant in VARIANTS {
            for int8 in [false, true] {
                let cfg = DetectorConfig::new(ds, variant, int8, pipelined());
                let g = StageGraph::build(&m, &cfg, 2048, false).expect("shipped config builds");
                let rep = verify::verify_all(&sim, &m, &g, 1);
                assert!(!rep.has_errors(), "{ds}/{variant:?}/int8={int8}:\n{rep}");
            }
        }
    }
}

/// The shipped cluster layout verifies with zero errors end-to-end
/// (per-box plans, routing-key counts, and every planned config's graph).
#[test]
fn shipped_cluster_spec_verifies_clean() {
    let planner = ServicePlanner::synthetic();
    let spec = ClusterSpec::parse("gpu+edgetpu:2,gpu:1,cpu+edgetpu:1").expect("spec parses");
    let cfg = DetectorConfig::new("synrgbd", Variant::PointSplit, true, pipelined());
    let configs = config_mix(&cfg, 2);
    let batch = BatchPolicy { max_batch: 4, max_wait_ms: 25.0 };
    let rep = verify::verify_cluster(&planner, &spec, &configs, 2048, &batch, &[1.0, 1.0]);
    assert!(!rep.has_errors(), "the shipped cluster spec must verify clean:\n{rep}");
}

// ----------------------------------------------------------- bad-graph corpus

#[test]
fn corpus_self_dep_is_g001() {
    let (m, mut g) = split_graph();
    g.nodes[5].extra_deps.push(5);
    let rep = verify::verify_graph(&m, &g);
    assert!(rep.fired("G001"), "self edge (static cycle) must be G001:\n{rep}");
}

#[test]
fn corpus_forward_dep_is_g001() {
    let (m, mut g) = split_graph();
    let last = g.nodes.len() - 1;
    g.nodes[0].spec.deps.push(last);
    let rep = verify::verify_graph(&m, &g);
    assert!(rep.fired("G001"), "forward edge (static cycle) must be G001:\n{rep}");
}

#[test]
fn corpus_dangling_dep_is_g002() {
    let (m, mut g) = split_graph();
    g.nodes[3].spec.deps.push(999);
    let rep = verify::verify_graph(&m, &g);
    assert!(rep.fired("G002"), "dangling dep must be G002:\n{rep}");
}

#[test]
fn corpus_artifact_drift_is_g003() {
    let (m, mut g) = split_graph();
    let nn = g.nodes.iter().position(|n| n.artifact.is_some()).expect("graph has NN nodes");
    g.nodes[nn].artifact = Some("synrgbd_pointsplit_vote_fp32".into());
    let rep = verify::verify_graph(&m, &g);
    assert!(rep.fired("G003"), "artifact drift from the derivation must be G003:\n{rep}");
}

#[test]
fn corpus_chain_metadata_drift_is_g004() {
    let (m, mut g) = split_graph();
    let decode = g.nodes.iter().position(|n| n.class == StageClass::Decode).expect("decode node");
    g.chains[0].levels[0].pm = decode;
    let rep = verify::verify_graph(&m, &g);
    assert!(rep.fired("G004"), "chain level pointing at a non-PM node must be G004:\n{rep}");
}

#[test]
fn corpus_point_op_on_edgetpu_is_p001() {
    let (m, mut g) = split_graph();
    let pm = g.nodes.iter().position(|n| matches!(n.class, StageClass::SaPm { .. })).expect("pm");
    g.nodes[pm].spec.device = DeviceKind::EdgeTpu;
    let rep = verify::verify_graph(&m, &g);
    assert!(rep.fired("P001"), "a point op placed on the EdgeTPU must be P001:\n{rep}");
}

#[test]
fn corpus_fp32_nn_on_edgetpu_is_p001() {
    let m = Manifest::synthetic();
    let cfg = DetectorConfig::new("synrgbd", Variant::PointSplit, false, pipelined());
    let g = StageGraph::build(&m, &cfg, 2048, false).expect("fp32 config builds");
    let mut specs = g.specs();
    let nn = specs
        .iter()
        .position(|s| s.workload.kind == WorkloadKind::NeuralNet)
        .expect("graph has NN stages");
    specs[nn].device = DeviceKind::EdgeTpu;
    let rep = verify::check_specs(&ScheduleSim::new(), &specs);
    assert!(rep.fired("P001"), "an fp32 NN forced onto the EdgeTPU must be P001:\n{rep}");
}

#[test]
fn corpus_oversized_stage_is_s001() {
    let (_, g) = split_graph();
    let mut specs = g.specs();
    specs[0].workload.mem_bytes = u64::MAX / 2;
    let rep = verify::check_specs(&ScheduleSim::new(), &specs);
    assert!(rep.fired("S001"), "a working set over device capacity must be S001:\n{rep}");
}

#[test]
fn corpus_free_cross_device_edge_is_s003() {
    let (_, mut g) = split_graph();
    let mut prod = None;
    'outer: for node in &g.nodes {
        for &d in &node.spec.deps {
            if g.nodes[d].spec.device != node.spec.device {
                prod = Some(d);
                break 'outer;
            }
        }
    }
    let prod = prod.expect("a pipelined split graph has cross-device edges");
    assert!(g.nodes[prod].spec.workload.wire_bytes > 0, "the edge must be priced today");
    g.nodes[prod].spec.workload.wire_bytes = 0;
    let rep = verify::verify_schedule(&ScheduleSim::new(), &g, 1);
    assert!(rep.fired("S003"), "a zero-byte cross-device edge must be S003:\n{rep}");
}

#[test]
fn corpus_tampered_fold_is_s004() {
    let (_, g) = split_graph();
    let base = g.specs();
    let mut folded = g.batch_fold(2);
    folded[0].workload.flops += 1;
    let rep = verify::check_fold(&base, &folded, 2);
    assert!(rep.fired("S004"), "a fold that is not exactly k-scaled must be S004:\n{rep}");
}

/// A point-op stage whose declared memory understates the SoA-padded
/// coordinate buffer the lane kernels actually stream. The shipped graphs
/// are sized from the grouped output tensor, which dwarfs the coordinate
/// arrays — so the rule stays silent on them and fires only on the tamper.
#[test]
fn corpus_understated_pointop_memory_is_s005() {
    let (m, mut g) = split_graph();
    let base = verify::verify_graph(&m, &g);
    assert!(!base.fired("S005"), "shipped graphs must not trip S005:\n{base}");
    let pm = g.nodes.iter().position(|n| matches!(n.class, StageClass::SaPm { .. })).expect("pm");
    g.nodes[pm].spec.workload.mem_bytes = 16;
    let rep = verify::verify_graph(&m, &g);
    assert!(rep.fired("S005"), "understated point-op mem_bytes must be S005:\n{rep}");
}

/// An NN stage whose declared memory understates the packed-weight +
/// activation footprint of its dense layer. Shipped graphs size NN stages
/// from streamed activations *plus* packed weights (`arch::nn_workload_of`),
/// so the rule stays silent on them and fires only on the tamper.
#[test]
fn corpus_understated_nn_memory_is_s007() {
    let (m, mut g) = split_graph();
    let base = verify::verify_graph(&m, &g);
    assert!(!base.fired("S007"), "shipped graphs must not trip S007:\n{base}");
    let nn = g.nodes.iter().position(|n| n.artifact.is_some()).expect("an NN node");
    g.nodes[nn].spec.workload.mem_bytes = 16;
    let rep = verify::verify_graph(&m, &g);
    assert!(rep.fired("S007"), "understated NN mem_bytes must be S007:\n{rep}");
}

/// The PR 2 merge bug, re-introduced as a fixture: `sa4_pm` lost its
/// dependency on the *other* pipeline's SA3 output, so a replayed plan
/// could read chain 1's geometry before it was written. The executor
/// soundness rule pins it — this is the regression the E family exists for.
#[test]
fn corpus_sa4_missing_cross_pipeline_dep_is_e001() {
    let (m, mut g) = split_graph();
    let dropped = g.chains[1].levels[2].nn;
    let sa4 = g.nodes.iter().position(|n| n.class == StageClass::Sa4Pm).expect("sa4 pm node");
    let before = g.nodes[sa4].spec.deps.len();
    g.nodes[sa4].spec.deps.retain(|&d| d != dropped);
    g.nodes[sa4].extra_deps.retain(|&d| d != dropped);
    assert!(g.nodes[sa4].spec.deps.len() < before, "fixture must drop a real edge");
    let rep = verify::verify_exec(&g);
    assert!(rep.fired("E001"), "the sa4 merge bug must be E001:\n{rep}");
    let full = verify::verify_graph(&m, &g);
    assert!(full.fired("E001"), "the full graph pipeline surfaces it too:\n{full}");
}

#[test]
fn corpus_double_write_is_e002() {
    let (_, mut g) = split_graph();
    let decode = g.nodes.iter().position(|n| n.class == StageClass::Decode).expect("decode node");
    let dup = g.nodes[decode].clone();
    g.nodes.push(dup);
    let rep = verify::verify_exec(&g);
    assert!(rep.fired("E002"), "two writers of one slot must be E002:\n{rep}");
}

#[test]
fn corpus_unproduced_read_is_e003() {
    let (_, mut g) = split_graph();
    // knock out the segmenter's write: Paint still reads the seg scores,
    // which nothing produces and nothing seeds (the scene is not pre-painted)
    let seg = g.nodes.iter().position(|n| n.class == StageClass::Seg).expect("seg node");
    g.nodes[seg].class = StageClass::Decode;
    let rep = verify::verify_exec(&g);
    assert!(rep.fired("E003"), "a read with no producer and no seed must be E003:\n{rep}");
}

#[test]
fn corpus_infeasible_box_type_is_c001_and_c004() {
    let planner = ServicePlanner::synthetic();
    // an EdgeTPU-only box cannot run point ops, so no config can be planned
    let spec = ClusterSpec::parse("edgetpu:1").expect("spec parses");
    let cfg = DetectorConfig::new("synrgbd", Variant::PointSplit, true, pipelined());
    let configs = config_mix(&cfg, 2);
    let batch = BatchPolicy { max_batch: 4, max_wait_ms: 25.0 };
    let rep = verify::verify_cluster(&planner, &spec, &configs, 2048, &batch, &[1.0, 1.0]);
    assert!(rep.fired("C001"), "a box type with no feasible plan must be C001:\n{rep}");
    assert!(rep.fired("C004"), "a cluster with no scalable template must be C004:\n{rep}");
}
