//! Cluster specification: heterogeneous box types and their per-box plans.
//!
//! Grammar (CLI `--boxes`): comma-separated `devices[:count]` groups,
//! devices joined by `+` — `"gpu+edgetpu:2,gpu,cpu+edgetpu"` is two
//! GPU+EdgeTPU boxes, one GPU-only box, one CPU+EdgeTPU box. Each box type
//! is planned independently: the placement search picks the best
//! [`Schedule`] for every detector config given exactly that box's
//! devices, so a CPU+EdgeTPU box serves the same configs as a GPU box —
//! just on its own optimal assignment, at its own capacity.
//!
//! [`Schedule`]: crate::coordinator::Schedule

use anyhow::{anyhow, Result};

use crate::config::parse_device;
use crate::coordinator::DetectorConfig;
use crate::graph::place::{self, Objective};
use crate::quant::{Granularity, StagePrecision};
use crate::serving::{BatchPolicy, ServicePlanner};
use crate::sim::DeviceKind;

/// Relative provisioning price of one device (arbitrary cost units; the
/// autoscaler ranks box types by capacity per unit, and the final report
/// bills the run in unit-seconds).
pub fn device_cost(d: DeviceKind) -> f64 {
    match d {
        DeviceKind::Cpu => 0.5,
        DeviceKind::Gpu => 3.0,
        DeviceKind::EdgeTpu => 1.0,
    }
}

/// One box *type*: its accelerator complement and price.
#[derive(Debug, Clone)]
pub struct BoxType {
    /// Canonical name, e.g. `"gpu+edgetpu"`.
    pub name: String,
    pub devices: Vec<DeviceKind>,
    pub cost_units: f64,
}

impl BoxType {
    /// Parse a `+`-joined device list (`"gpu+edgetpu"`, `"cpu"`, …).
    pub fn parse(s: &str) -> Result<BoxType> {
        let mut devices: Vec<DeviceKind> = Vec::new();
        for part in s.split('+') {
            let part = part.trim();
            if part.is_empty() {
                return Err(anyhow!("empty device in box type '{s}'"));
            }
            let d = parse_device(part)?;
            if !devices.contains(&d) {
                devices.push(d);
            }
        }
        if devices.is_empty() {
            return Err(anyhow!("box type '{s}' names no devices"));
        }
        let cost_units = devices.iter().map(|d| device_cost(*d)).sum();
        let name = devices
            .iter()
            .map(|d| d.name().to_ascii_lowercase())
            .collect::<Vec<_>>()
            .join("+");
        Ok(BoxType { name, devices, cost_units })
    }
}

/// The fleet as provisioned at t=0: one [`BoxType`] entry per box instance.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub boxes: Vec<BoxType>,
}

impl ClusterSpec {
    /// Parse `"gpu+edgetpu:2,gpu:1,cpu+edgetpu"` (count defaults to 1).
    pub fn parse(s: &str) -> Result<ClusterSpec> {
        let mut boxes = Vec::new();
        for group in s.split(',') {
            let group = group.trim();
            if group.is_empty() {
                continue;
            }
            let (ty, count) = match group.rsplit_once(':') {
                Some((ty, n)) => {
                    let n: usize = n
                        .trim()
                        .parse()
                        .map_err(|_| anyhow!("bad box count in '{group}' (want TYPE:N)"))?;
                    (ty, n)
                }
                None => (group, 1),
            };
            let bt = BoxType::parse(ty)?;
            for _ in 0..count {
                boxes.push(bt.clone());
            }
        }
        if boxes.is_empty() {
            return Err(anyhow!("cluster spec '{s}' describes no boxes"));
        }
        Ok(ClusterSpec { boxes })
    }

    /// Number of distinct box types in the fleet.
    pub fn num_box_types(&self) -> usize {
        let mut names: Vec<&str> = self.boxes.iter().map(|b| b.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        names.len()
    }
}

/// A provisioned box: its type, the cluster's configs re-scheduled for its
/// devices, and its steady-state capacity at the fleet batch size.
#[derive(Debug, Clone)]
pub struct BoxPlan {
    pub box_type: BoxType,
    /// Same config list (and `Request::key` indexing) as the cluster's,
    /// each with this box's placement-search winner as its schedule.
    pub configs: Vec<DetectorConfig>,
    /// Admission-weighted capacity under the load mix.
    pub capacity_rps: f64,
}

/// Plan one box type: run the throughput-objective placement search per
/// config over exactly this box's devices. Errors if any config has no
/// feasible assignment (e.g. an EdgeTPU-only box — it cannot run point
/// ops at all).
pub fn plan_box(
    planner: &ServicePlanner,
    bt: &BoxType,
    base_configs: &[DetectorConfig],
    num_points: usize,
    batch: &BatchPolicy,
    mix: &[f64],
) -> Result<BoxPlan> {
    assert!(!base_configs.is_empty(), "planning a box with no configs");
    let mut configs = Vec::with_capacity(base_configs.len());
    for cfg in base_configs {
        let schedule = place::best_schedule(
            planner.manifest(),
            cfg,
            num_points,
            batch.max_batch,
            &bt.devices,
            Objective::Throughput,
        )?;
        let mut c = cfg.clone();
        c.schedule = schedule;
        configs.push(c);
    }
    let capacity_rps =
        planner.mixed_capacity_rps(&configs, num_points, batch.max_batch, mix)?;
    Ok(BoxPlan { box_type: bt.clone(), configs, capacity_rps })
}

/// `n` distinguishable detector configs for affinity experiments: the base
/// config with the head precision cycled through the granularity ladder.
/// Each lands in its own batcher key and planner cache entry (the schemes
/// differ), which is exactly what config-affinity routing exploits.
pub fn config_mix(base: &DetectorConfig, n: usize) -> Vec<DetectorConfig> {
    const LADDER: [Granularity; 6] = [
        Granularity::Role,
        Granularity::Channel,
        Granularity::Layer,
        Granularity::Group(2),
        Granularity::Group(4),
        Granularity::Group(8),
    ];
    (0..n.max(1))
        .map(|i| {
            let mut c = base.clone();
            c.scheme = c.scheme.with_head(StagePrecision::Int8(LADDER[i % LADDER.len()]));
            c
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Schedule, Variant};

    fn base_cfg() -> DetectorConfig {
        DetectorConfig::new(
            "synrgbd",
            Variant::PointSplit,
            true,
            Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
        )
    }

    #[test]
    fn parses_heterogeneous_spec() {
        let spec = ClusterSpec::parse("gpu+edgetpu:2, gpu:1 ,cpu+edgetpu").unwrap();
        assert_eq!(spec.boxes.len(), 4);
        assert_eq!(spec.num_box_types(), 3);
        assert_eq!(spec.boxes[0].name, "gpu+edgetpu");
        assert_eq!(spec.boxes[0].devices, vec![DeviceKind::Gpu, DeviceKind::EdgeTpu]);
        assert_eq!(spec.boxes[2].name, "gpu");
        assert_eq!(spec.boxes[3].devices, vec![DeviceKind::Cpu, DeviceKind::EdgeTpu]);
        // a GPU+EdgeTPU box costs more than a CPU+EdgeTPU box
        assert!(spec.boxes[0].cost_units > spec.boxes[3].cost_units);
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(ClusterSpec::parse("").is_err());
        assert!(ClusterSpec::parse("quantum:2").is_err());
        assert!(ClusterSpec::parse("gpu:abc").is_err());
        assert!(BoxType::parse("gpu++edgetpu").is_err());
    }

    #[test]
    fn plans_pick_per_box_schedules() {
        let planner = ServicePlanner::synthetic();
        let cfgs = vec![base_cfg()];
        let batch = BatchPolicy::default();
        let split = plan_box(
            &planner,
            &BoxType::parse("gpu+edgetpu").unwrap(),
            &cfgs,
            2048,
            &batch,
            &[1.0],
        )
        .unwrap();
        // the paper's box recovers the paper's assignment
        assert_eq!(
            split.configs[0].schedule,
            Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu }
        );
        let gpu_only =
            plan_box(&planner, &BoxType::parse("gpu").unwrap(), &cfgs, 2048, &batch, &[1.0])
                .unwrap();
        assert_eq!(gpu_only.configs[0].schedule.nn_dev(), DeviceKind::Gpu);
        // heterogeneity is real: the split box out-serves the GPU-only box
        assert!(
            split.capacity_rps > gpu_only.capacity_rps,
            "split {} rps vs gpu-only {} rps",
            split.capacity_rps,
            gpu_only.capacity_rps
        );
        // an EdgeTPU-only box is infeasible (no point ops), not a panic
        assert!(plan_box(
            &planner,
            &BoxType::parse("edgetpu").unwrap(),
            &cfgs,
            2048,
            &batch,
            &[1.0]
        )
        .is_err());
    }

    #[test]
    fn config_mix_yields_distinct_schemes() {
        let mix = config_mix(&base_cfg(), 4);
        assert_eq!(mix.len(), 4);
        for i in 0..mix.len() {
            for j in (i + 1)..mix.len() {
                assert_ne!(mix[i].scheme, mix[j].scheme, "configs {i} and {j} collide");
            }
        }
    }
}
