//! Integration tests for the host-parallel stage executor.
//!
//! Everything here runs offline: the synthetic runtime executes NN stages on
//! the deterministic host surrogate, so the full functional pipeline —
//! detections included — is exercised without artifacts or a PJRT backend.
//!
//! The two core contracts:
//! 1. **Determinism** — parallel execution produces bit-identical detections
//!    and identical `StageSpec` DAGs to sequential execution, for every
//!    variant (property over seeds).
//! 2. **The merge() dependency fix** — `sa4_pm` depends on *both*
//!    pipelines' SA3 NN stages and never starts before either finishes in
//!    the simulated timeline. (On the pre-fix code the dep list held only
//!    the max stage index, so the structural assertion below fails there.)

use pointsplit::coordinator::{DetectorConfig, ScenePipeline, Schedule, Variant};
use pointsplit::data::{self, generate_scene, SYNRGBD};
use pointsplit::exec::HostExec;
use pointsplit::runtime::Runtime;
use pointsplit::serving::dispatch::PipelineExecutor;
use pointsplit::serving::{
    run_traffic, ArrivalPattern, BatchPolicy, LoadGen, ServicePlanner, SloPolicy, TrafficScenario,
};
use pointsplit::sim::DeviceKind;

const VARIANTS: [Variant; 4] =
    [Variant::VoteNet, Variant::PointPainting, Variant::RandomSplit, Variant::PointSplit];

fn pipelined() -> Schedule {
    Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu }
}

fn cfg(variant: Variant, schedule: Schedule) -> DetectorConfig {
    DetectorConfig::new("synrgbd", variant, true, schedule)
}

#[test]
fn parallel_execution_bit_identical_to_sequential_all_variants() {
    let rt = Runtime::synthetic();
    for variant in VARIANTS {
        for seed in [1u64, 42, 1234] {
            let scene = generate_scene(seed, &SYNRGBD);
            let seq = ScenePipeline::new(&rt, cfg(variant, pipelined()))
                .with_host_exec(HostExec::Sequential)
                .run(&scene, seed)
                .expect("sequential run");
            assert!(
                !seq.stage_specs.is_empty(),
                "{variant:?}: pipeline must declare stages"
            );
            for threads in [2usize, 4, 8] {
                let par = ScenePipeline::new(&rt, cfg(variant, pipelined()))
                    .with_host_exec(HostExec::Parallel { threads })
                    .run(&scene, seed)
                    .expect("parallel run");
                assert_eq!(
                    seq.detections, par.detections,
                    "{variant:?} seed {seed} threads {threads}: detections diverged"
                );
                assert_eq!(
                    seq.stage_specs, par.stage_specs,
                    "{variant:?} seed {seed} threads {threads}: stage DAG diverged"
                );
                assert_eq!(
                    seq.timeline.total_ms.to_bits(),
                    par.timeline.total_ms.to_bits(),
                    "{variant:?} seed {seed} threads {threads}: simulated timeline diverged"
                );
            }
        }
    }
}

#[test]
fn parallel_execution_bit_identical_across_schedules() {
    let rt = Runtime::synthetic();
    for schedule in [
        pipelined(),
        Schedule::Sequential { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
        Schedule::SingleDevice(DeviceKind::Gpu),
    ] {
        let scene = generate_scene(7, &SYNRGBD);
        let seq = ScenePipeline::new(&rt, cfg(Variant::PointSplit, schedule))
            .with_host_exec(HostExec::Sequential)
            .run(&scene, 7)
            .unwrap();
        let par = ScenePipeline::new(&rt, cfg(Variant::PointSplit, schedule))
            .with_host_exec(HostExec::Parallel { threads: 4 })
            .run(&scene, 7)
            .unwrap();
        assert_eq!(seq.detections, par.detections, "{schedule:?}");
        assert_eq!(seq.stage_specs, par.stage_specs, "{schedule:?}");
    }
}

/// The merge() dependency regression: `sa4_pm` must wait for **both**
/// pipelines' SA3 NN stages — structurally (dep edges) and in the simulated
/// timeline. The old code kept only `max(a.last_nn, b.last_nn)`.
#[test]
fn sa4_waits_for_both_pipelines() {
    let rt = Runtime::synthetic();
    let scene = generate_scene(3, &SYNRGBD);
    let out = ScenePipeline::new(&rt, cfg(Variant::PointSplit, pipelined()))
        .run(&scene, 3)
        .unwrap();
    let idx = |name: &str| {
        out.stage_specs
            .iter()
            .position(|s| s.name == name)
            .unwrap_or_else(|| panic!("stage '{name}' missing"))
    };
    let (nn_a, nn_b, pm4) = (idx("sa3_normal_nn"), idx("sa3_bias_nn"), idx("sa4_pm"));
    let deps = &out.stage_specs[pm4].deps;
    assert!(
        deps.contains(&nn_a) && deps.contains(&nn_b),
        "sa4_pm deps {deps:?} must include both sa3 NN stages ({nn_a}, {nn_b})"
    );
    // and the simulated timeline must respect it
    let t = |name: &str| out.timeline.stage(name).unwrap_or_else(|| panic!("{name} interval"));
    let pm4_start = t("sa4_pm").compute_start_ms;
    assert!(
        pm4_start >= t("sa3_normal_nn").end_ms - 1e-9
            && pm4_start >= t("sa3_bias_nn").end_ms - 1e-9,
        "sa4_pm at {pm4_start} started before an SA3 NN finished ({} / {})",
        t("sa3_normal_nn").end_ms,
        t("sa3_bias_nn").end_ms
    );
}

/// Same property on the serving planner's mirrored DAG.
#[test]
fn planner_sa4_waits_for_both_pipelines() {
    let planner = ServicePlanner::synthetic();
    let stages = planner.stages(&cfg(Variant::PointSplit, pipelined()), 2048, false).unwrap();
    let idx = |name: &str| stages.iter().position(|s| s.name == name).unwrap();
    let deps = &stages[idx("sa4_pm")].deps;
    assert!(
        deps.contains(&idx("sa3_normal_nn")) && deps.contains(&idx("sa3_bias_nn")),
        "planner sa4_pm deps {deps:?}"
    );
}

/// The pipeline's recorded DAG and the serving planner's analytic DAG are
/// the same object — any drift between them is a bug (this is the class the
/// merge() bug belonged to).
#[test]
fn pipeline_dag_matches_serving_planner() {
    let rt = Runtime::synthetic();
    let planner = ServicePlanner::synthetic();
    for variant in VARIANTS {
        let c = cfg(variant, pipelined());
        let scene = generate_scene(11, &SYNRGBD);
        let out = ScenePipeline::new(&rt, c.clone()).run(&scene, 11).unwrap();
        let planned = planner.stages(&c, SYNRGBD.num_points, false).unwrap();
        assert_eq!(planned, out.stage_specs, "{variant:?}: planner DAG drifted from pipeline");
    }
}

#[test]
fn consecutive_matching_skips_seg_stage() {
    let rt = Runtime::synthetic();
    let pipe = ScenePipeline::new(&rt, cfg(Variant::PointSplit, pipelined()));
    let scene = generate_scene(5, &SYNRGBD);
    let (first, scores) = pipe.run_with_scores(&scene, 5, None).unwrap();
    assert!(first.stage_specs.iter().any(|s| s.name == "seg"));
    let scores = scores.expect("painted run returns scores");
    let (second, _) = pipe.run_with_scores(&scene, 5, Some(&scores)).unwrap();
    assert!(
        !second.stage_specs.iter().any(|s| s.name == "seg"),
        "consecutive matching must skip the segmenter"
    );
    assert!(second.timeline.total_ms < first.timeline.total_ms + 1e-9);
    // determinism holds on the skip path too
    let (second_par, _) = pipe.run_with_scores(&scene, 5, Some(&scores)).unwrap();
    assert_eq!(second.detections, second_par.detections);
}

/// End-to-end functional serving on the synthetic runtime: the per-scene
/// worker pool executes dispatched batches and the report carries mAP.
#[test]
fn traffic_gateway_executes_functionally_offline() {
    let planner = ServicePlanner::synthetic();
    let c = cfg(Variant::PointSplit, pipelined());
    let ds = data::dataset("synrgbd").unwrap();
    let cap = planner.capacity_rps(&c, ds.num_points, 2).unwrap();
    let sc = TrafficScenario {
        name: "functional-offline".into(),
        configs: vec![c],
        num_points: ds.num_points,
        load: LoadGen::simple(
            ArrivalPattern::Poisson { rate_rps: cap * 0.5 },
            4_000.0,
            2_000.0,
            13,
        ),
        queue_capacity: 16,
        batch: BatchPolicy { max_batch: 2, max_wait_ms: 25.0 },
        policy: SloPolicy::None,
    };
    let rt = Runtime::synthetic();
    let exec = PipelineExecutor::with_workers(&rt, ds, 2);
    let rep = run_traffic(&sc, &planner, Some(&exec)).unwrap();
    assert!(rep.completed > 0, "no requests completed");
    assert!(
        rep.map_25.is_some(),
        "functional execution must report mAP on the surrogate backend"
    );
}
