//! Parametric furniture shape programs (mirror of scene.py `_CLASS_SPECS`).
//!
//! Each program returns cuboid parts `(cx, cy, cz, sx, sy, sz)` in the
//! object's canonical frame: resting on z=0, footprint centered at origin.

pub struct ClassSpec {
    pub name: &'static str,
    pub program: fn(f64, f64, f64) -> Vec<[f64; 6]>,
    pub w: (f64, f64),
    pub d: (f64, f64),
    pub h: (f64, f64),
}

fn legs(w: f64, d: f64, h: f64) -> Vec<[f64; 6]> {
    let t = 0.05;
    let dx = w / 2.0 - t / 2.0;
    let dy = d / 2.0 - t / 2.0;
    let mut out = Vec::with_capacity(4);
    for sx in [-1.0, 1.0] {
        for sy in [-1.0, 1.0] {
            out.push([sx * dx, sy * dy, h / 2.0, t, t, h]);
        }
    }
    out
}

fn parts_bed(w: f64, d: f64, h: f64) -> Vec<[f64; 6]> {
    vec![
        [0.0, 0.0, h * 0.35, w, d, h * 0.7],
        [0.0, -d / 2.0 + 0.05, h * 0.85, w, 0.1, h * 1.7],
    ]
}

fn parts_table(w: f64, d: f64, h: f64) -> Vec<[f64; 6]> {
    let t = 0.06;
    let mut out = vec![[0.0, 0.0, h - t / 2.0, w, d, t]];
    out.extend(legs(w, d, h - t));
    out
}

fn parts_sofa(w: f64, d: f64, h: f64) -> Vec<[f64; 6]> {
    let seat_h = h * 0.55;
    let mut out = vec![[0.0, 0.0, seat_h / 2.0, w, d, seat_h]];
    out.push([0.0, -d / 2.0 + 0.08, h / 2.0 + seat_h * 0.2, w, 0.16, h]);
    let arm_w = 0.12;
    for s in [-1.0, 1.0] {
        out.push([s * (w / 2.0 - arm_w / 2.0), 0.0, h * 0.4, arm_w, d, h * 0.8]);
    }
    out
}

fn parts_chair(w: f64, d: f64, h: f64) -> Vec<[f64; 6]> {
    let seat_h = h * 0.55;
    let seat_t = 0.05;
    let mut out = vec![[0.0, 0.0, seat_h - seat_t / 2.0, w, d, seat_t]];
    out.extend(legs(w, d, seat_h - seat_t));
    out.push([0.0, -d / 2.0 + 0.025, seat_h + (h - seat_h) / 2.0, w, 0.05, h - seat_h]);
    out
}

fn parts_toilet(w: f64, d: f64, h: f64) -> Vec<[f64; 6]> {
    let bowl_h = h * 0.55;
    vec![
        [0.0, d * 0.1, bowl_h / 2.0, w, d * 0.8, bowl_h],
        [0.0, -d / 2.0 + 0.07, bowl_h + (h - bowl_h) / 2.0, w, 0.14, h - bowl_h],
    ]
}

fn parts_desk(w: f64, d: f64, h: f64) -> Vec<[f64; 6]> {
    let t = 0.05;
    let mut out = vec![[0.0, 0.0, h - t / 2.0, w, d, t]];
    out.extend(legs(w, d, h - t));
    out.push([w / 2.0 - 0.15, 0.0, (h - t) / 2.0, 0.3, d * 0.9, h - t]);
    out
}

fn parts_box(w: f64, d: f64, h: f64) -> Vec<[f64; 6]> {
    vec![[0.0, 0.0, h / 2.0, w, d, h]]
}

pub const CLASS_SPECS: [ClassSpec; 10] = [
    ClassSpec { name: "bed", program: parts_bed, w: (1.6, 2.1), d: (1.4, 1.9), h: (0.4, 0.6) },
    ClassSpec { name: "table", program: parts_table, w: (1.0, 1.8), d: (0.6, 1.1), h: (0.65, 0.78) },
    ClassSpec { name: "sofa", program: parts_sofa, w: (1.5, 2.2), d: (0.8, 1.0), h: (0.7, 0.8) },
    ClassSpec { name: "chair", program: parts_chair, w: (0.4, 0.55), d: (0.4, 0.55), h: (0.75, 0.95) },
    ClassSpec { name: "toilet", program: parts_toilet, w: (0.35, 0.45), d: (0.5, 0.6), h: (0.7, 0.8) },
    ClassSpec { name: "desk", program: parts_desk, w: (1.1, 1.5), d: (0.6, 0.8), h: (0.7, 0.78) },
    ClassSpec { name: "dresser", program: parts_box, w: (0.8, 1.2), d: (0.4, 0.6), h: (0.8, 1.1) },
    ClassSpec { name: "nightstand", program: parts_box, w: (0.4, 0.6), d: (0.4, 0.6), h: (0.5, 0.7) },
    ClassSpec { name: "bookshelf", program: parts_box, w: (0.6, 1.0), d: (0.25, 0.35), h: (1.5, 2.0) },
    ClassSpec { name: "bathtub", program: parts_box, w: (1.4, 1.8), d: (0.7, 0.9), h: (0.5, 0.6) },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parts_within_bounds() {
        for spec in CLASS_SPECS.iter() {
            let w = (spec.w.0 + spec.w.1) / 2.0;
            let d = (spec.d.0 + spec.d.1) / 2.0;
            let h = (spec.h.0 + spec.h.1) / 2.0;
            for part in (spec.program)(w, d, h) {
                let [cx, cy, cz, sx, sy, sz] = part;
                assert!(sx > 0.0 && sy > 0.0 && sz > 0.0, "{}: degenerate part", spec.name);
                assert!(cx.abs() + sx / 2.0 <= w / 2.0 + 1e-6, "{}: x overflow", spec.name);
                assert!(cy.abs() + sy / 2.0 <= d / 2.0 + 1e-6, "{}: y overflow", spec.name);
                // headboards/backs may exceed nominal height (visual detail),
                // but must stay grounded
                assert!(cz - sz / 2.0 >= -1e-6, "{}: below floor", spec.name);
            }
        }
    }

    #[test]
    fn mean_sizes_match_manifest_table() {
        // midpoints here are the MEAN_SIZES table shared with python
        let expect = [
            [1.85, 1.65, 0.50],
            [1.40, 0.85, 0.715],
            [1.85, 0.90, 0.75],
            [0.475, 0.475, 0.85],
            [0.40, 0.55, 0.75],
            [1.30, 0.70, 0.74],
            [1.00, 0.50, 0.95],
            [0.50, 0.50, 0.60],
            [0.80, 0.30, 1.75],
            [1.60, 0.80, 0.55],
        ];
        for (spec, e) in CLASS_SPECS.iter().zip(expect.iter()) {
            assert!(((spec.w.0 + spec.w.1) / 2.0 - e[0]).abs() < 0.06, "{}", spec.name);
            assert!(((spec.d.0 + spec.d.1) / 2.0 - e[1]).abs() < 0.06, "{}", spec.name);
            assert!(((spec.h.0 + spec.h.1) / 2.0 - e[2]).abs() < 0.06, "{}", spec.name);
        }
    }
}
