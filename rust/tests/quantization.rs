//! End-to-end tests of the QuantScheme execution layer (ISSUE 3 acceptance):
//! the INT8 surrogate path is bit-consistent with the `ActQuant` QDQ
//! reference, a detect run over synthetic scenes keeps the role-based
//! scheme's mAP within tolerance of fp heads, and the simulated timeline
//! reflects per-precision device placement and latency.
//!
//! Everything runs offline on the synthetic runtime (deterministic host
//! surrogate — no artifacts, no PJRT).

use pointsplit::coordinator::serve::serve;
use pointsplit::coordinator::{DetectorConfig, ScenePipeline, Schedule, Variant};
use pointsplit::data::{self, generate_scene, SYNRGBD};
use pointsplit::quant::{ActQuant, QTensor, StagePrecision};
use pointsplit::runtime::Runtime;
use pointsplit::serving::slo;
use pointsplit::sim::{DeviceKind, Precision};
use pointsplit::util::rng::Rng;
use pointsplit::util::tensor::Tensor;

fn pipelined() -> Schedule {
    Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu }
}

#[test]
fn int8_surrogate_bit_consistent_with_qdq_reference() {
    // the manifest-declared vote spec, calibrated on a random activation:
    // QTensor quantize -> dequantize must equal ActQuant::qdq bit-for-bit
    let rt = Runtime::synthetic();
    let meta = rt.manifest.artifact("synrgbd_pointsplit_vote_int8_role").unwrap().clone();
    let spec = rt.manifest.stage_quant(&meta);
    let mut r = Rng::new(11);
    let c = spec.cout;
    let data: Vec<f32> = (0..64 * c).map(|_| r.normal_scaled(0.0, 2.0) as f32).collect();
    let t = Tensor::new(vec![64, c], data);
    let act = spec.calibrate(&t);
    let q = QTensor::quantize(&t, &act).expect("quantize");
    let deq = q.dequantize();
    let mut reference = t.clone();
    act.qdq(&mut reference).expect("qdq");
    for (a, b) in deq.data.iter().zip(reference.data.iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "i8 round trip drifted from QDQ");
    }
    // malformed activations are a Result, not a worker-killing panic
    let bad = ActQuant::calibrate(&[0.0; 4], &[1.0; 4], &[vec![0, 1, 2, 3]]);
    assert!(QTensor::quantize(&t, &bad).is_err());
}

#[test]
fn role_head_map_within_tolerance_of_fp_and_timeline_reflects_precision() {
    // the acceptance run: same int8 backbone, fp32 heads vs role-quantized
    // heads. Accuracy must hold; the simulated timeline must not — the
    // fp32 heads fall back to the GPU at fp32 rates while the role heads
    // stay on the EdgeTPU.
    let rt = Runtime::synthetic();
    let ds = data::dataset("synrgbd").unwrap();
    let scenes = 8;
    let cfg_role = DetectorConfig::new("synrgbd", Variant::PointSplit, true, pipelined());
    let mut cfg_fp = cfg_role.clone();
    cfg_fp.set_head_precision("fp32").unwrap();
    assert_eq!(cfg_fp.scheme.vote, StagePrecision::Fp32);
    assert!(cfg_fp.int8(), "backbone stays int8");

    let rep_fp = serve(&rt, &cfg_fp, ds, scenes, 2, 640_000).expect("fp-head serve");
    let rep_role = serve(&rt, &cfg_role, ds, scenes, 2, 640_000).expect("role-head serve");
    assert!(
        (rep_fp.map_25 - rep_role.map_25).abs() <= 0.25,
        "role-based heads drifted from fp: {:.3} vs {:.3}",
        rep_role.map_25,
        rep_fp.map_25
    );

    // per-precision placement + latency in the simulated timeline
    let scene = generate_scene(21, &SYNRGBD);
    let out_fp = ScenePipeline::new(&rt, cfg_fp).run(&scene, 21).unwrap();
    let out_role = ScenePipeline::new(&rt, cfg_role).run(&scene, 21).unwrap();
    let vote_fp = out_fp.timeline.stage("vote").expect("vote interval (fp)");
    let vote_role = out_role.timeline.stage("vote").expect("vote interval (role)");
    assert_eq!(vote_fp.device, DeviceKind::Gpu, "fp32 head cannot sit on the EdgeTPU");
    assert_eq!(vote_role.device, DeviceKind::EdgeTpu, "int8 head belongs on the EdgeTPU");
    let dur = |s: &pointsplit::sim::schedule::StageInterval| s.end_ms - s.compute_start_ms;
    assert!(
        dur(vote_role) < dur(vote_fp),
        "EdgeTPU int8 vote ({:.1} ms) must beat GPU fp32 vote ({:.1} ms)",
        dur(vote_role),
        dur(vote_fp)
    );
    // the declared DAG carries the precision the executor and sim consumed
    let spec_of = |out: &pointsplit::coordinator::PipelineOutput, name: &str| {
        out.stage_specs.iter().find(|s| s.name == name).unwrap().precision
    };
    assert_eq!(spec_of(&out_fp, "vote"), Precision::Fp32);
    assert_eq!(spec_of(&out_role, "vote"), Precision::Int8);
    assert_eq!(spec_of(&out_role, "sa1_normal_nn"), Precision::Int8);
}

#[test]
fn degraded_scheme_executes_and_keeps_role_heads_on_npu() {
    // the SLO fast path swaps stage specs on an fp32 config: the whole DAG
    // must execute (backbone artifacts run at the group granularity the
    // name does not encode) with heads at role fidelity on the NPU
    let rt = Runtime::synthetic();
    let slow = DetectorConfig::new("synrgbd", Variant::PointSplit, false, pipelined());
    let fast = slo::degraded_config(&slow);
    let scene = generate_scene(33, &SYNRGBD);
    let out = ScenePipeline::new(&rt, fast.clone()).run(&scene, 33).expect("degraded run");
    assert!(out.timeline.total_ms > 0.0);
    let vote = out.stage_specs.iter().find(|s| s.name == "vote").unwrap();
    assert_eq!(vote.precision, Precision::Int8);
    assert_eq!(vote.device, DeviceKind::EdgeTpu);
    // degraded must also be faster than the fp32 path it degrades from
    let out_slow = ScenePipeline::new(&rt, slow).run(&scene, 33).expect("fp32 run");
    assert!(
        out.timeline.total_ms < out_slow.timeline.total_ms,
        "degraded {:.0} ms must beat fp32 {:.0} ms",
        out.timeline.total_ms,
        out_slow.timeline.total_ms
    );
    // and its detections differ from fp32 only by quantization, not by a
    // different model: both runs see the same scene structure
    assert!(!out.detections.is_empty() || !out_slow.detections.is_empty());
}
