//! Open-loop traffic gateway: admission control, dynamic batching, and
//! SLO-aware degradation on top of the per-scene pipeline.
//!
//! The closed-loop `coordinator::serve` answers "how fast can this box chew
//! through N scenes"; this subsystem answers the serving question the
//! ROADMAP's north star actually poses: requests *arrive on their own
//! clock*, queues build, deadlines pass, and the system must decide what to
//! run, what to coalesce, and what to drop. The pieces compose left to
//! right:
//!
//! ```text
//!  loadgen ─▶ queue ─▶ batcher ─▶ slo ─▶ dispatch ─▶ plan/ScheduleSim
//!  (Poisson,  (bounded  (size/age  (degrade (virtual-   (calibrated
//!   MMPP,      +prio,    window,    /shed)   time two-    GPU/NPU
//!   diurnal)   drops)    per key)            lane loop)   timeline)
//! ```
//!
//! All time in the gateway is **simulated milliseconds** on the calibrated
//! device model: a request's end-to-end latency is its queueing delay plus
//! batch-formation delay plus the `sim::ScheduleSim` makespan of the batch
//! it rode in. That means overload behaviour (p99 blow-up, goodput
//! collapse, the win from degradation) reflects the paper's hardware, not
//! the host this binary happens to run on. See `docs/SERVING.md`.
//!
//! The dispatch loop's per-box state machine is exported as
//! [`BoxEngine`]: `cluster::run_cluster` drives one engine per edge box
//! behind a router to scale the gateway out to a heterogeneous fleet (see
//! `docs/CLUSTER.md`).
//!
//! Streaming clients ([`Request::client`] != 0) get a bounded per-box
//! session cache: frames classified REUSE/PARTIAL by the temporal model
//! ride cheaper graphs (the [`crate::temporal`] reuse path), and the
//! stale-tracks SLO rung can force warm sessions onto their cached REUSE
//! tail under overload. See `docs/STREAMING.md`.

pub mod batcher;
pub mod dispatch;
pub mod loadgen;
pub mod plan;
pub mod queue;
pub mod slo;

pub use batcher::{Batch, BatchPolicy};
pub use dispatch::{
    run_traffic, run_traffic_trace, BoxEngine, EngineStats, OutcomeKind, RequestOutcome,
    ServeTrafficReport, TrafficScenario,
};
pub use loadgen::{ArrivalPattern, LoadGen, Request};
pub use plan::{PlanCost, ServicePlanner};
pub use queue::{AdmissionQueue, AdmitResult, QueueStats};
pub use slo::{SloDecision, SloPolicy};
