//! QuantScheme: precision as a typed, schedulable property of every stage.
//!
//! The paper's role-based group-wise quantization (§4.3, Tables 7/11) used
//! to live only in the QDQ mirror of `quant::mod` while the live pipeline
//! reduced INT8 to a boolean that swapped artifact names. This module makes
//! the scheme a first-class execution layer:
//!
//! - [`StagePrecision`] — what one stage class executes at (fp32, or INT8 at
//!   a [`Granularity`]); the property the scheduler prices (an fp32 stage
//!   cannot sit on the EdgeTPU) and the serving SLO policy swaps per batch.
//! - [`QuantScheme`] — the per-stage-class assignment a [`DetectorConfig`]
//!   carries: backbone / vote head / proposal head, independently settable,
//!   so degradation keeps the accuracy-critical head at role fidelity while
//!   dropping backbone groups to plain INT8.
//! - [`QuantSpec`] — one stage's calibratable spec: precision + declared
//!   output-channel role partition ([`crate::runtime::Manifest::stage_quant`]
//!   declares these per artifact).
//! - [`QTensor`] — real `i8` storage with per-channel affine parameters;
//!   `quantize -> dequantize` is bit-consistent with [`ActQuant::qdq`].
//! - [`derive_roles`] — the calibration pass: clusters a stage's output
//!   channels by dynamic range into role groups (the Fig. 6 structure,
//!   recovered from data instead of hand-declared).
//!
//! [`DetectorConfig`]: crate::coordinator::DetectorConfig

use anyhow::{anyhow, Result};

use super::{channel_minmax, partition, ActQuant, Granularity};
use crate::sim::Precision;
use crate::util::tensor::Tensor;

/// Even-group count the degraded backbone drops to (see
/// [`QuantScheme::degraded`]).
pub const DEGRADED_BACKBONE_GROUPS: usize = 4;

/// Numeric execution mode of one stage class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StagePrecision {
    Fp32,
    /// INT8 with an activation-quantization granularity over channels.
    Int8(Granularity),
}

impl StagePrecision {
    pub fn is_int8(self) -> bool {
        matches!(self, StagePrecision::Int8(_))
    }

    /// The device simulator's two-regime precision.
    pub fn sim(self) -> Precision {
        if self.is_int8() {
            Precision::Int8
        } else {
            Precision::Fp32
        }
    }

    /// Artifact-name suffix for head networks (vote/prop export one
    /// executable per granularity).
    pub fn head_name(self) -> &'static str {
        match self {
            StagePrecision::Fp32 => "fp32",
            StagePrecision::Int8(g) => match g {
                Granularity::Layer => "int8_layer",
                Granularity::Group(_) => "int8_group",
                Granularity::Channel => "int8_channel",
                Granularity::Role => "int8_role",
            },
        }
    }

    /// Artifact-name suffix for backbone/segmenter networks (exported at a
    /// single INT8 granularity).
    pub fn backbone_name(self) -> &'static str {
        if self.is_int8() {
            "int8"
        } else {
            "fp32"
        }
    }

    /// Cache-key name: like [`Self::head_name`] but discriminating the
    /// even-group count.
    pub fn key_name(self) -> String {
        match self {
            StagePrecision::Int8(Granularity::Group(n)) => format!("int8_group{n}"),
            p => p.head_name().to_string(),
        }
    }

    /// Parse an artifact precision label ("fp32", "int8", "int8_role", ...).
    pub fn parse(s: &str) -> Option<StagePrecision> {
        Some(match s {
            "fp32" => StagePrecision::Fp32,
            "int8" | "int8_layer" => StagePrecision::Int8(Granularity::Layer),
            "int8_group" => StagePrecision::Int8(Granularity::Group(DEGRADED_BACKBONE_GROUPS)),
            "int8_channel" => StagePrecision::Int8(Granularity::Channel),
            "int8_role" => StagePrecision::Int8(Granularity::Role),
            _ => return None,
        })
    }
}

/// Per-stage-class precision assignment of one detector configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QuantScheme {
    /// 2D segmenter, SA backbone, and FP layer.
    pub backbone: StagePrecision,
    /// Vote head.
    pub vote: StagePrecision,
    /// Proposal head.
    pub prop: StagePrecision,
}

impl QuantScheme {
    pub fn fp32() -> QuantScheme {
        QuantScheme {
            backbone: StagePrecision::Fp32,
            vote: StagePrecision::Fp32,
            prop: StagePrecision::Fp32,
        }
    }

    /// Fully-INT8 scheme: layer-wise backbone, `head` granularity heads.
    pub fn int8(head: Granularity) -> QuantScheme {
        QuantScheme {
            backbone: StagePrecision::Int8(Granularity::Layer),
            vote: StagePrecision::Int8(head),
            prop: StagePrecision::Int8(head),
        }
    }

    /// Build from the artifact precision labels used across benches/CLI.
    pub fn from_names(backbone: &str, head: &str) -> Option<QuantScheme> {
        let b = StagePrecision::parse(backbone)?;
        let h = StagePrecision::parse(head)?;
        Some(QuantScheme { backbone: b, vote: h, prop: h })
    }

    /// Same scheme with both head stages at `head`.
    pub fn with_head(self, head: StagePrecision) -> QuantScheme {
        QuantScheme { vote: head, prop: head, ..self }
    }

    /// Precision of the stage executing artifact network `net`.
    pub fn for_net(self, net: &str) -> StagePrecision {
        match net {
            "vote" => self.vote,
            "prop" => self.prop,
            _ => self.backbone,
        }
    }

    /// The SLO fast path: backbone groups dropped to plain INT8 (even
    /// groups — cheap, EdgeTPU-eligible) while the accuracy-critical heads
    /// are kept at (or raised to) role-based fidelity. This is the
    /// "swap a stage subset's QuantSpec" move — not a config flag.
    pub fn degraded(self) -> QuantScheme {
        QuantScheme {
            backbone: StagePrecision::Int8(Granularity::Group(DEGRADED_BACKBONE_GROUPS)),
            vote: StagePrecision::Int8(Granularity::Role),
            prop: StagePrecision::Int8(Granularity::Role),
        }
    }

    /// Discriminating key for plan/pipeline caches.
    pub fn key(self) -> String {
        format!(
            "{}/{}/{}",
            self.backbone.key_name(),
            self.vote.key_name(),
            self.prop.key_name()
        )
    }
}

/// Quantization spec of one stage: precision plus the declared
/// output-channel role partition. [`QuantSpec::calibrate`] turns observed
/// activations into an [`ActQuant`]; when the granularity is `Role` and no
/// (matching) partition was declared, the roles are derived from the data
/// ([`derive_roles`]).
#[derive(Debug, Clone, PartialEq)]
pub struct QuantSpec {
    pub precision: StagePrecision,
    /// Output channel count of the stage.
    pub cout: usize,
    /// Declared role partition (empty -> derived at calibration time).
    pub roles: Vec<Vec<usize>>,
}

impl QuantSpec {
    pub fn new(precision: StagePrecision, cout: usize, roles: Vec<Vec<usize>>) -> QuantSpec {
        QuantSpec { precision, cout, roles }
    }

    pub fn fp32(cout: usize) -> QuantSpec {
        QuantSpec::new(StagePrecision::Fp32, cout, Vec::new())
    }

    /// Channel partition for an observed activation range (`lo`/`hi` are
    /// per-channel minima/maxima; their length wins over `self.cout` so a
    /// spec never panics on an unexpected width).
    pub fn groups_for(&self, lo: &[f32], hi: &[f32]) -> Vec<Vec<usize>> {
        let c = lo.len();
        match self.precision {
            StagePrecision::Fp32 => vec![(0..c).collect()],
            StagePrecision::Int8(Granularity::Role) => {
                let covered: usize = self.roles.iter().map(|g| g.len()).sum();
                if !self.roles.is_empty() && covered == c {
                    self.roles.clone()
                } else {
                    derive_roles(lo, hi, 4)
                }
            }
            StagePrecision::Int8(g) => partition(g, c, &self.roles),
        }
    }

    /// Calibrate an activation quantizer for an observed `(N, C)` tensor.
    pub fn calibrate(&self, t: &Tensor) -> ActQuant {
        let (lo, hi) = channel_minmax(t);
        let groups = self.groups_for(&lo, &hi);
        ActQuant::calibrate(&lo, &hi, &groups)
    }

    /// Quantization parameters this spec stores for the stage (3 per
    /// channel group, matching `quantize.quant_param_count`).
    pub fn param_count(&self) -> usize {
        let groups = match self.precision {
            StagePrecision::Fp32 => return 0,
            StagePrecision::Int8(Granularity::Layer) => 1,
            StagePrecision::Int8(Granularity::Channel) => self.cout.max(1),
            StagePrecision::Int8(Granularity::Group(n)) => n.clamp(1, self.cout.max(1)),
            StagePrecision::Int8(Granularity::Role) => self.roles.len().max(1),
        };
        3 * groups
    }
}

/// Genuinely quantized activation tensor: `i8` codes plus the per-channel
/// affine parameters that produced them. The `quantize -> dequantize`
/// round trip is bit-consistent with [`ActQuant::qdq`] (every code is an
/// integer in `[-128, 127]`, exactly representable in f32, and the
/// dequantization expression is identical).
#[derive(Debug, Clone, PartialEq)]
pub struct QTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i8>,
    /// Per-channel (expanded) scale / zero-point, as calibrated.
    pub scale: Vec<f32>,
    pub zero: Vec<f32>,
}

impl QTensor {
    /// An empty tensor whose buffers [`QTensor::quantize_into`] can reuse.
    pub fn empty() -> QTensor {
        QTensor { shape: Vec::new(), data: Vec::new(), scale: Vec::new(), zero: Vec::new() }
    }

    /// Quantize a `(N, C)` tensor with a calibrated quantizer.
    pub fn quantize(t: &Tensor, q: &ActQuant) -> Result<QTensor> {
        let mut qt = QTensor::empty();
        qt.quantize_into(t, q)?;
        Ok(qt)
    }

    /// Quantize into this tensor's existing storage. The packed GEMM path
    /// keeps one scratch `QTensor` per thread and re-quantizes into it each
    /// call, so the steady-state int8 hot path allocates no code buffer.
    /// Produces codes bit-identical to [`QTensor::quantize`].
    pub fn quantize_into(&mut self, t: &Tensor, q: &ActQuant) -> Result<()> {
        let c = q.scale.len();
        if t.row_len() != c {
            return Err(anyhow!(
                "quantize: activation width {} != calibrated channels {c}",
                t.row_len()
            ));
        }
        self.data.clear();
        self.data.reserve(t.data.len());
        for row in 0..t.rows() {
            for (i, &v) in t.row(row).iter().enumerate() {
                let code = (v / q.scale[i] + q.zero[i]).round().clamp(-128.0, 127.0);
                self.data.push(code as i8);
            }
        }
        self.shape.clone_from(&t.shape);
        self.scale.clone_from(&q.scale);
        self.zero.clone_from(&q.zero);
        Ok(())
    }

    /// Recover the f32 view (bit-consistent with [`ActQuant::qdq`]).
    pub fn dequantize(&self) -> Tensor {
        let c = self.scale.len().max(1);
        let data = self
            .data
            .iter()
            .enumerate()
            .map(|(i, &q)| (q as f32 - self.zero[i % c]) * self.scale[i % c])
            .collect();
        Tensor::new(self.shape.clone(), data)
    }

    /// Bytes this tensor occupies on the wire (1 per element).
    pub fn size_bytes(&self) -> usize {
        self.data.len()
    }
}

/// Calibration pass: derive a role partition from a stage's observed
/// output-channel ranges. Channels cluster by dynamic-range magnitude on a
/// log scale; a new group opens where consecutive (sorted) channels differ
/// by more than 4x in range. This recovers the paper's Fig. 6 structure —
/// tight xyz offsets vs wide classification logits vs medium regression
/// residuals — without a hand-declared partition.
pub fn derive_roles(lo: &[f32], hi: &[f32], max_groups: usize) -> Vec<Vec<usize>> {
    let c = lo.len();
    if c == 0 {
        return Vec::new();
    }
    let max_groups = max_groups.max(1);
    let logr: Vec<f64> = (0..c)
        .map(|i| ((hi[i] - lo[i]).max(1e-12) as f64).log10())
        .collect();
    let mut order: Vec<usize> = (0..c).collect();
    order.sort_by(|&a, &b| logr[a].partial_cmp(&logr[b]).unwrap().then(a.cmp(&b)));
    // candidate cut before sorted position i, weighted by the range gap
    let mut gaps: Vec<(f64, usize)> = order
        .windows(2)
        .enumerate()
        .map(|(i, w)| (logr[w[1]] - logr[w[0]], i + 1))
        .collect();
    gaps.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
    let threshold = 4.0f64.log10();
    let mut cuts: Vec<usize> = gaps
        .iter()
        .take(max_groups - 1)
        .filter(|&&(g, _)| g > threshold)
        .map(|&(_, i)| i)
        .collect();
    cuts.sort_unstable();
    let mut groups = Vec::with_capacity(cuts.len() + 1);
    let mut start = 0usize;
    for cut in cuts.into_iter().chain(std::iter::once(c)) {
        let mut g: Vec<usize> = order[start..cut].to_vec();
        g.sort_unstable();
        groups.push(g);
        start = cut;
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn head_like(n: usize, seed: u64) -> Tensor {
        let mut r = Rng::new(seed);
        let c = 80;
        let mut data = Vec::with_capacity(n * c);
        for _ in 0..n {
            for ch in 0..c {
                let sigma = if ch < 3 {
                    0.05
                } else if ch < 40 {
                    8.0
                } else {
                    0.8
                };
                data.push(r.normal_scaled(0.0, sigma) as f32);
            }
        }
        Tensor::new(vec![n, c], data)
    }

    #[test]
    fn qtensor_roundtrip_bit_consistent_with_qdq() {
        let t = head_like(128, 7);
        let spec = QuantSpec::new(StagePrecision::Int8(Granularity::Role), 80, Vec::new());
        let act = spec.calibrate(&t);
        let q = QTensor::quantize(&t, &act).expect("quantize");
        let deq = q.dequantize();
        let mut reference = t.clone();
        act.qdq(&mut reference).expect("qdq");
        assert_eq!(deq.shape, reference.shape);
        for (a, b) in deq.data.iter().zip(reference.data.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "QTensor drifted from QDQ reference");
        }
        assert_eq!(q.size_bytes(), t.len());
    }

    #[test]
    fn qtensor_rejects_width_mismatch() {
        let t = head_like(4, 1);
        let act = ActQuant::calibrate(&[0.0; 3], &[1.0; 3], &[vec![0, 1, 2]]);
        assert!(QTensor::quantize(&t, &act).is_err());
    }

    #[test]
    fn derive_roles_recovers_head_clusters() {
        let t = head_like(256, 9);
        let (lo, hi) = channel_minmax(&t);
        let roles = derive_roles(&lo, &hi, 4);
        assert_eq!(roles.len(), 3, "expected 3 role clusters, got {roles:?}");
        let covered: usize = roles.iter().map(|g| g.len()).sum();
        assert_eq!(covered, 80);
        let xyz = roles
            .iter()
            .find(|g| g.contains(&0))
            .expect("group containing channel 0");
        assert_eq!(xyz[..], [0usize, 1, 2], "xyz channels must cluster alone");
    }

    #[test]
    fn derive_roles_degenerate_inputs() {
        assert!(derive_roles(&[], &[], 4).is_empty());
        let one = derive_roles(&[0.0], &[1.0], 4);
        assert_eq!(one, vec![vec![0]]);
        // homogeneous channels collapse to a single group
        let hom = derive_roles(&[0.0; 16], &[1.0; 16], 4);
        assert_eq!(hom.len(), 1);
    }

    #[test]
    fn scheme_names_roundtrip() {
        for (b, h) in [
            ("fp32", "fp32"),
            ("int8", "int8_layer"),
            ("int8", "int8_group"),
            ("int8", "int8_channel"),
            ("int8", "int8_role"),
        ] {
            let s = QuantScheme::from_names(b, h).expect("parse");
            assert_eq!(s.backbone.backbone_name(), b);
            assert_eq!(s.vote.head_name(), h);
            assert_eq!(s.prop.head_name(), h);
        }
        assert!(QuantScheme::from_names("int4", "fp32").is_none());
    }

    #[test]
    fn degraded_keeps_role_heads_drops_backbone_groups() {
        let fast = QuantScheme::fp32().degraded();
        assert_eq!(
            fast.backbone,
            StagePrecision::Int8(Granularity::Group(DEGRADED_BACKBONE_GROUPS))
        );
        assert_eq!(fast.vote, StagePrecision::Int8(Granularity::Role));
        assert_eq!(fast.prop, StagePrecision::Int8(Granularity::Role));
        // cache keys discriminate degraded from plain int8
        assert_ne!(fast.key(), QuantScheme::int8(Granularity::Role).key());
    }

    #[test]
    fn spec_param_counts_match_quantize_py() {
        let vote_roles = vec![(0..3).collect::<Vec<_>>(), (3..131).collect()];
        let mk = |p| QuantSpec::new(p, 131, vote_roles.clone()).param_count();
        assert_eq!(mk(StagePrecision::Fp32), 0);
        assert_eq!(mk(StagePrecision::Int8(Granularity::Layer)), 3);
        assert_eq!(mk(StagePrecision::Int8(Granularity::Role)), 6);
        assert_eq!(mk(StagePrecision::Int8(Granularity::Group(2))), 6);
        assert_eq!(mk(StagePrecision::Int8(Granularity::Channel)), 3 * 131);
    }
}
