//! Integration tests for the open-loop traffic gateway: scenario plumbing,
//! policy behaviour under load, and composition of queueing delay with the
//! calibrated device timeline. Everything here runs on the synthetic
//! manifest — no artifacts or PJRT backend required.

use pointsplit::coordinator::{DetectorConfig, Schedule, Variant};
use pointsplit::serving::dispatch::run_traffic_trace;
use pointsplit::serving::{
    run_traffic, ArrivalPattern, BatchPolicy, LoadGen, ServicePlanner, SloPolicy, TrafficScenario,
};
use pointsplit::sim::DeviceKind;

fn split_cfg() -> DetectorConfig {
    DetectorConfig::new(
        "synrgbd",
        Variant::PointSplit,
        true,
        Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
    )
}

fn scenario(
    planner: &ServicePlanner,
    pattern_of: impl Fn(f64) -> ArrivalPattern,
    load_mult: f64,
    policy: SloPolicy,
    seed: u64,
) -> TrafficScenario {
    let cfg = split_cfg();
    let batch = BatchPolicy { max_batch: 4, max_wait_ms: 25.0 };
    let cap = planner.capacity_rps(&cfg, 2048, batch.max_batch).unwrap();
    TrafficScenario {
        name: format!("it-{load_mult}x"),
        configs: vec![cfg],
        num_points: 2048,
        load: LoadGen::simple(pattern_of(cap * load_mult), 30_000.0, 1_200.0, seed),
        queue_capacity: 48,
        batch,
        policy,
    }
}

fn poisson(rate: f64) -> ArrivalPattern {
    ArrivalPattern::Poisson { rate_rps: rate }
}

fn bursty(rate: f64) -> ArrivalPattern {
    // mean = (0.4r*6000 + 2.5r*2000) / 8000 = 0.925r
    ArrivalPattern::Bursty {
        base_rps: rate * 0.4,
        burst_rps: rate * 2.5,
        mean_burst_ms: 2_000.0,
        mean_calm_ms: 6_000.0,
    }
}

#[test]
fn poisson_and_bursty_run_end_to_end() {
    let planner = ServicePlanner::synthetic();
    for pattern_of in [poisson as fn(f64) -> ArrivalPattern, bursty] {
        let sc = scenario(&planner, pattern_of, 0.8, SloPolicy::Degrade, 5);
        let (rep, outcomes) = run_traffic_trace(&sc, &planner, None).unwrap();
        assert!(rep.arrivals > 10, "{}: no traffic generated", rep.pattern);
        assert_eq!(outcomes.len(), rep.arrivals);
        assert!(rep.completed > 0);
        assert!(rep.latency_ms.p50 > 0.0);
        assert!(rep.latency_ms.p50 <= rep.latency_ms.p95);
        assert!(rep.latency_ms.p95 <= rep.latency_ms.p99);
        assert!(rep.makespan_s >= rep.duration_s);
        assert!((0.0..=1.0).contains(&rep.slo_attainment));
        assert!(rep.util_gpu >= 0.0 && rep.util_gpu <= 1.05, "GPU util {}", rep.util_gpu);
        assert!(rep.util_npu >= 0.0 && rep.util_npu <= 1.05, "NPU util {}", rep.util_npu);
        assert!(rep.map_25.is_none(), "no functional executor attached");
    }
}

#[test]
fn latency_includes_queueing_delay() {
    // under heavy load, end-to-end latency must exceed pure service time:
    // queueing + batching delay is charged into the simulated clock
    let planner = ServicePlanner::synthetic();
    let service = planner.cost(&split_cfg(), 2048, 4, false).unwrap().total_ms;
    let calm =
        run_traffic(&scenario(&planner, poisson, 0.2, SloPolicy::None, 11), &planner, None)
            .unwrap();
    let busy =
        run_traffic(&scenario(&planner, poisson, 1.6, SloPolicy::None, 11), &planner, None)
            .unwrap();
    assert!(
        busy.latency_ms.p95 > calm.latency_ms.p95 + 0.25 * service,
        "overload p95 ({:.0} ms) must reflect queueing beyond calm p95 ({:.0} ms)",
        busy.latency_ms.p95,
        calm.latency_ms.p95
    );
    assert!(busy.queue_wait_ms.p95 > calm.queue_wait_ms.p95);
}

#[test]
fn overload_drops_are_accounted() {
    let planner = ServicePlanner::synthetic();
    let rep =
        run_traffic(&scenario(&planner, poisson, 2.0, SloPolicy::None, 23), &planner, None)
            .unwrap();
    assert!(
        rep.rejected_full + rep.expired > 0,
        "2x overload with a bounded queue must drop something"
    );
    assert_eq!(rep.completed + rep.rejected_full + rep.expired + rep.shed_slo, rep.arrivals);
}

#[test]
fn degrade_policy_wins_under_overload_both_patterns() {
    let planner = ServicePlanner::synthetic();
    for pattern_of in [poisson as fn(f64) -> ArrivalPattern, bursty] {
        let none =
            run_traffic(&scenario(&planner, pattern_of, 2.0, SloPolicy::None, 31), &planner, None)
                .unwrap();
        let deg = run_traffic(
            &scenario(&planner, pattern_of, 2.0, SloPolicy::Degrade, 31),
            &planner,
            None,
        )
        .unwrap();
        assert!(
            deg.goodput_rps > none.goodput_rps,
            "{}: degrade goodput {:.2} must beat none {:.2}",
            none.pattern,
            deg.goodput_rps,
            none.goodput_rps
        );
        assert!(
            deg.slo_attainment > none.slo_attainment,
            "{}: degrade attainment {:.2} must beat none {:.2}",
            none.pattern,
            deg.slo_attainment,
            none.slo_attainment
        );
        assert!(deg.degraded > 0);
    }
}

#[test]
fn shed_policy_never_dispatches_doomed_work() {
    let planner = ServicePlanner::synthetic();
    let rep =
        run_traffic(&scenario(&planner, poisson, 2.0, SloPolicy::Shed, 37), &planner, None)
            .unwrap();
    // everything dispatched was predicted on time; lateness can only come
    // from the (conservative) prediction itself, so on-time must dominate
    assert!(rep.shed_slo > 0, "2x overload must shed");
    assert!(
        rep.on_time as f64 >= 0.9 * rep.completed as f64,
        "shed policy completed {} but only {} on time",
        rep.completed,
        rep.on_time
    );
}

#[test]
fn high_priority_class_served_first() {
    let planner = ServicePlanner::synthetic();
    let mut sc = scenario(&planner, poisson, 1.5, SloPolicy::None, 41);
    sc.load.hi_frac = 0.3;
    let (rep, outcomes) = run_traffic_trace(&sc, &planner, None).unwrap();
    assert!(rep.arrivals > 20);
    // regenerate the (deterministic) trace to recover each id's class
    let arrivals = sc.load.generate();
    let rate = |class: usize| {
        let total = arrivals.iter().filter(|r| r.class == class).count();
        let ok = outcomes
            .iter()
            .filter(|o| o.on_time && arrivals[o.id as usize].class == class)
            .count();
        (ok as f64, total.max(1) as f64)
    };
    let (hi_ok, hi_n) = rate(0);
    let (lo_ok, lo_n) = rate(1);
    assert!(
        hi_ok / hi_n >= lo_ok / lo_n - 1e-9,
        "high priority on-time rate {:.2} below low priority {:.2}",
        hi_ok / hi_n,
        lo_ok / lo_n
    );
}

#[test]
fn mixed_keys_batch_separately() {
    let planner = ServicePlanner::synthetic();
    let sched = Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu };
    let cfg_a = DetectorConfig::new("synrgbd", Variant::PointSplit, true, sched);
    let cfg_b = DetectorConfig::new("synrgbd", Variant::VoteNet, true, sched);
    let cap = planner.capacity_rps(&cfg_a, 2048, 4).unwrap();
    let mut load = LoadGen::simple(ArrivalPattern::Poisson { rate_rps: cap }, 20_000.0, 1_500.0, 47);
    load.mix = vec![1.0, 1.0];
    let sc = TrafficScenario {
        name: "mixed".into(),
        configs: vec![cfg_a, cfg_b],
        num_points: 2048,
        load,
        queue_capacity: 48,
        batch: BatchPolicy { max_batch: 4, max_wait_ms: 25.0 },
        policy: SloPolicy::Degrade,
        };
    let (rep, outcomes) = run_traffic_trace(&sc, &planner, None).unwrap();
    assert_eq!(outcomes.len(), rep.arrivals);
    assert!(rep.completed > 0);
    assert_eq!(rep.completed + rep.rejected_full + rep.expired + rep.shed_slo, rep.arrivals);
}

#[test]
fn loadgen_is_bit_deterministic_per_seed_all_patterns() {
    // the cluster router and the A/B policy comparisons both rely on a
    // seed being a pure function: every field of every request must match
    // bit-for-bit across regenerations, for every arrival process
    let patterns = [
        ArrivalPattern::Poisson { rate_rps: 35.0 },
        ArrivalPattern::Bursty {
            base_rps: 8.0,
            burst_rps: 70.0,
            mean_burst_ms: 1_500.0,
            mean_calm_ms: 5_000.0,
        },
        ArrivalPattern::Diurnal { base_rps: 4.0, peak_rps: 50.0, period_s: 20.0 },
    ];
    for pattern in patterns {
        for seed in [1u64, 42, 9_999] {
            let mk = || {
                let mut lg = LoadGen::simple(pattern, 25_000.0, 800.0, seed);
                lg.hi_frac = 0.25;
                lg.mix = vec![2.0, 1.0, 1.0];
                lg.generate()
            };
            let (a, b) = (mk(), mk());
            assert!(!a.is_empty(), "{}: empty trace", pattern.name());
            assert_eq!(a.len(), b.len(), "{} seed {seed}", pattern.name());
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.arrival_ms.to_bits(), y.arrival_ms.to_bits());
                assert_eq!(x.deadline_ms.to_bits(), y.deadline_ms.to_bits());
                assert_eq!(x.seed, y.seed);
                assert_eq!(x.class, y.class);
                assert_eq!(x.key, y.key);
            }
        }
    }
    // and different seeds actually change the trace (no seed plumbing bug)
    let t1 = LoadGen::simple(ArrivalPattern::Poisson { rate_rps: 35.0 }, 25_000.0, 800.0, 1)
        .generate();
    let t2 = LoadGen::simple(ArrivalPattern::Poisson { rate_rps: 35.0 }, 25_000.0, 800.0, 2)
        .generate();
    assert!(
        t1.len() != t2.len()
            || t1.iter().zip(&t2).any(|(x, y)| x.arrival_ms.to_bits() != y.arrival_ms.to_bits()),
        "different seeds produced identical traces"
    );
}

#[test]
fn report_capacity_consistent_with_planner() {
    let planner = ServicePlanner::synthetic();
    let sc = scenario(&planner, poisson, 1.0, SloPolicy::None, 53);
    let rep = run_traffic(&sc, &planner, None).unwrap();
    let cap = planner.capacity_rps(&split_cfg(), 2048, 4).unwrap();
    assert!((rep.capacity_rps - cap).abs() < 1e-9);
    assert!((rep.offered_rps - cap).abs() / cap < 1e-9);
}
