//! The property the stage-graph IR exists to guarantee: the serving
//! planner's simulated timeline and the executing pipeline's timeline are
//! **identical, stage for stage** — same names, same devices, same
//! precisions, same start/end instants — across every `Schedule` ×
//! `Variant` combination. Both sides obtain their `StageSpec` sequence
//! from the same `StageGraph` constructor, so any divergence here means a
//! pass mutated what it should only have lowered.

use pointsplit::coordinator::{DetectorConfig, ScenePipeline, Schedule, Variant};
use pointsplit::data::{generate_scene, SYNRGBD};
use pointsplit::runtime::Runtime;
use pointsplit::serving::ServicePlanner;
use pointsplit::sim::{DeviceKind, Timeline};

const VARIANTS: [Variant; 4] =
    [Variant::VoteNet, Variant::PointPainting, Variant::RandomSplit, Variant::PointSplit];

fn schedules() -> Vec<Schedule> {
    let pairs = [
        (DeviceKind::Gpu, DeviceKind::EdgeTpu),
        (DeviceKind::Cpu, DeviceKind::EdgeTpu),
        (DeviceKind::Gpu, DeviceKind::Cpu),
    ];
    let mut out = vec![
        Schedule::SingleDevice(DeviceKind::Gpu),
        Schedule::SingleDevice(DeviceKind::Cpu),
    ];
    for (pd, nd) in pairs {
        out.push(Schedule::Sequential { point_dev: pd, nn_dev: nd });
        out.push(Schedule::Pipelined { point_dev: pd, nn_dev: nd });
    }
    out
}

fn assert_timeline_eq(pipe: &Timeline, plan: &Timeline, ctx: &str) {
    assert_eq!(pipe.stages.len(), plan.stages.len(), "{ctx}: stage count diverged");
    for (a, b) in pipe.stages.iter().zip(plan.stages.iter()) {
        assert_eq!(a.name, b.name, "{ctx}: stage order diverged");
        assert_eq!(a.device, b.device, "{ctx}: '{}' placed differently", a.name);
        assert_eq!(a.precision, b.precision, "{ctx}: '{}' precision diverged", a.name);
        assert_eq!(
            a.start_ms.to_bits(),
            b.start_ms.to_bits(),
            "{ctx}: '{}' start {} vs {}",
            a.name,
            a.start_ms,
            b.start_ms
        );
        assert_eq!(
            a.end_ms.to_bits(),
            b.end_ms.to_bits(),
            "{ctx}: '{}' end {} vs {}",
            a.name,
            a.end_ms,
            b.end_ms
        );
    }
    assert_eq!(pipe.total_ms.to_bits(), plan.total_ms.to_bits(), "{ctx}: total_ms");
}

/// The acceptance property: planner timeline == pipeline timeline,
/// stage for stage, for every Schedule × Variant (INT8 — the paper's
/// operating point).
#[test]
fn planner_timeline_matches_pipeline_every_schedule_and_variant() {
    let rt = Runtime::synthetic();
    let planner = ServicePlanner::synthetic();
    let scene = generate_scene(17, &SYNRGBD);
    for schedule in schedules() {
        for variant in VARIANTS {
            let cfg = DetectorConfig::new("synrgbd", variant, true, schedule);
            let ctx = format!("{variant:?} / {schedule:?} / int8");
            let out = ScenePipeline::new(&rt, cfg.clone())
                .run(&scene, 17)
                .unwrap_or_else(|e| panic!("{ctx}: pipeline failed: {e:#}"));
            // the DAGs are the same object...
            let planned = planner.stages(&cfg, SYNRGBD.num_points, false).unwrap();
            assert_eq!(planned, out.stage_specs, "{ctx}: specs diverged");
            // ...and so are the timelines, bit for bit
            let plan_tl = planner.timeline(&cfg, SYNRGBD.num_points, 1, false).unwrap();
            assert_timeline_eq(&out.timeline, &plan_tl, &ctx);
        }
    }
}

/// Same property at fp32 — exercises the per-precision device fallback
/// (fp32 NN stages cannot sit on the EdgeTPU).
#[test]
fn planner_timeline_matches_pipeline_fp32() {
    let rt = Runtime::synthetic();
    let planner = ServicePlanner::synthetic();
    let scene = generate_scene(23, &SYNRGBD);
    for schedule in [
        Schedule::SingleDevice(DeviceKind::Gpu),
        Schedule::Sequential { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
        Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
    ] {
        for variant in VARIANTS {
            let cfg = DetectorConfig::new("synrgbd", variant, false, schedule);
            let ctx = format!("{variant:?} / {schedule:?} / fp32");
            let out = ScenePipeline::new(&rt, cfg.clone())
                .run(&scene, 23)
                .unwrap_or_else(|e| panic!("{ctx}: pipeline failed: {e:#}"));
            let plan_tl = planner.timeline(&cfg, SYNRGBD.num_points, 1, false).unwrap();
            assert_timeline_eq(&out.timeline, &plan_tl, &ctx);
            // fp32 NN stages must have fallen back off the EdgeTPU
            for s in &out.timeline.stages {
                if s.precision == pointsplit::sim::Precision::Fp32 {
                    assert_ne!(s.device, DeviceKind::EdgeTpu, "{ctx}: '{}'", s.name);
                }
            }
        }
    }
}

/// Consecutive matching (skip_seg) preserves the equivalence: the pipeline
/// run that reuses previous-frame scores matches the planner's
/// skip_seg graph.
#[test]
fn skip_seg_timelines_match() {
    let rt = Runtime::synthetic();
    let planner = ServicePlanner::synthetic();
    let scene = generate_scene(31, &SYNRGBD);
    let cfg = DetectorConfig::new(
        "synrgbd",
        Variant::PointSplit,
        true,
        Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
    );
    let pipe = ScenePipeline::new(&rt, cfg.clone());
    let (first, scores) = pipe.run_with_scores(&scene, 31, None).unwrap();
    assert_timeline_eq(
        &first.timeline,
        &planner.timeline(&cfg, SYNRGBD.num_points, 1, false).unwrap(),
        "full frame",
    );
    let scores = scores.expect("painted run returns scores");
    let (second, _) = pipe.run_with_scores(&scene, 31, Some(&scores)).unwrap();
    assert_timeline_eq(
        &second.timeline,
        &planner.timeline(&cfg, SYNRGBD.num_points, 1, true).unwrap(),
        "consecutive-matching frame",
    );
}

/// Mixed schemes (fp32 heads over an int8 backbone) keep the equivalence —
/// the per-stage placement decision is part of the shared graph, not of
/// either consumer.
#[test]
fn mixed_scheme_timelines_match() {
    let rt = Runtime::synthetic();
    let planner = ServicePlanner::synthetic();
    let scene = generate_scene(41, &SYNRGBD);
    let mut cfg = DetectorConfig::new(
        "synrgbd",
        Variant::PointSplit,
        true,
        Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
    );
    cfg.set_head_precision("fp32").unwrap();
    let out = ScenePipeline::new(&rt, cfg.clone()).run(&scene, 41).unwrap();
    let plan_tl = planner.timeline(&cfg, SYNRGBD.num_points, 1, false).unwrap();
    assert_timeline_eq(&out.timeline, &plan_tl, "mixed scheme");
    let vote = out.timeline.stage("vote").expect("vote interval");
    assert_eq!(vote.device, DeviceKind::Gpu, "fp32 vote falls back to the point device");
}
