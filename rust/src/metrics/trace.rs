//! Chrome-trace (chrome://tracing / Perfetto) export of simulated timelines.
//!
//! `pointsplit detect --trace out.json` writes the two-lane schedule as a
//! trace-event file: one "thread" per device, compute slices and transfer
//! slices separated — the Fig. 2/3 diagrams, but interactive.

use crate::sim::{DeviceKind, Timeline};
use crate::util::json::Json;

fn device_tid(kind: DeviceKind) -> (u64, &'static str) {
    match kind {
        DeviceKind::Gpu => (1, "GPU (point manipulation)"),
        DeviceKind::EdgeTpu => (2, "EdgeTPU (neural nets)"),
        DeviceKind::Cpu => (3, "CPU"),
    }
}

/// Serialize a [`Timeline`] to the Chrome trace-event JSON format.
pub fn to_chrome_trace(tl: &Timeline) -> String {
    let mut events: Vec<Json> = Vec::new();
    // thread names
    for kind in [DeviceKind::Gpu, DeviceKind::EdgeTpu, DeviceKind::Cpu] {
        let (tid, name) = device_tid(kind);
        events.push(Json::obj(vec![
            ("ph", Json::Str("M".into())),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(tid as f64)),
            ("name", Json::Str("thread_name".into())),
            (
                "args",
                Json::obj(vec![("name", Json::Str(name.into()))]),
            ),
        ]));
    }
    for s in &tl.stages {
        let (tid, _) = device_tid(s.device);
        if s.comm_ms > 0.0 {
            events.push(Json::obj(vec![
                ("ph", Json::Str("X".into())),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(tid as f64)),
                ("name", Json::Str(format!("xfer:{}", s.name))),
                ("cat", Json::Str("transfer".into())),
                ("ts", Json::Num(s.start_ms * 1000.0)),
                ("dur", Json::Num(s.comm_ms * 1000.0)),
            ]));
        }
        events.push(Json::obj(vec![
            ("ph", Json::Str("X".into())),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(tid as f64)),
            ("name", Json::Str(s.name.clone())),
            ("cat", Json::Str("compute".into())),
            ("ts", Json::Num(s.compute_start_ms * 1000.0)),
            ("dur", Json::Num((s.end_ms - s.compute_start_ms) * 1000.0)),
        ]));
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Precision, ScheduleSim, StageSpec, Workload, WorkloadKind};

    #[test]
    fn trace_roundtrips_as_json() {
        let stages = vec![
            StageSpec {
                name: "a".into(),
                device: DeviceKind::Gpu,
                precision: Precision::Fp32,
                workload: Workload {
                    kind: WorkloadKind::PointOp,
                    flops: 1_000_000,
                    mem_bytes: 0,
                    wire_bytes: 100,
                },
                deps: vec![],
            },
            StageSpec {
                name: "b".into(),
                device: DeviceKind::EdgeTpu,
                precision: Precision::Int8,
                workload: Workload {
                    kind: WorkloadKind::NeuralNet,
                    flops: 10_000_000,
                    mem_bytes: 0,
                    wire_bytes: 100,
                },
                deps: vec![0],
            },
        ];
        let tl = ScheduleSim::new().run(&stages);
        let trace = to_chrome_trace(&tl);
        let parsed = Json::parse(&trace).unwrap();
        let events = parsed.req("traceEvents").as_arr().unwrap();
        // 3 thread metas + 2 compute + 1 transfer (b crosses devices)
        assert!(events.len() >= 6, "{}", events.len());
        assert!(events.iter().any(|e| e.req("name").as_str() == Some("b")));
        assert!(events.iter().any(|e| e.get("cat").and_then(|c| c.as_str()) == Some("transfer")));
    }
}
