"""Synthetic scene generator invariants (python side of the parity pair)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import common, scene

settings.register_profile("ci", max_examples=8, deadline=None)
settings.load_profile("ci")


def test_shapes_and_determinism():
    a = scene.generate_scene(7, common.SYNRGBD)
    b = scene.generate_scene(7, common.SYNRGBD)
    assert a.points.shape == (common.SYNRGBD.num_points, 3)
    assert a.image.shape == (common.IMG_SIZE, common.IMG_SIZE, 3)
    assert a.seg_mask.shape == (common.IMG_SIZE, common.IMG_SIZE)
    np.testing.assert_array_equal(a.points, b.points)
    np.testing.assert_array_equal(a.seg_mask, b.seg_mask)


@given(seed=st.integers(0, 500))
def test_object_count_in_range(seed):
    s = scene.generate_scene(seed, common.SYNRGBD)
    assert 1 <= len(s.objects) <= common.SYNRGBD.max_objects


@given(seed=st.integers(0, 200))
def test_boxes_well_formed(seed):
    s = scene.generate_scene(seed, common.SYNSCAN)
    boxes = s.boxes()
    if len(boxes):
        assert (boxes[:, 3:6] > 0.05).all()
        assert (boxes[:, 6] >= 0).all() and (boxes[:, 6] < 2 * np.pi + 1e-5).all()
        assert (boxes[:, 7] >= 0).all() and (boxes[:, 7] < common.NUM_CLASS).all()


def test_seg_mask_label_range_and_fg_presence():
    s = scene.generate_scene(11, common.SYNRGBD)
    assert s.seg_mask.min() >= 0 and s.seg_mask.max() <= common.NUM_CLASS
    assert (s.seg_mask > 0).sum() > 20


def test_image_in_unit_range():
    s = scene.generate_scene(12, common.SYNRGBD)
    assert s.image.min() >= 0.0 and s.image.max() <= 1.0


def test_paint_with_oracle_mask_marks_objects():
    s = scene.generate_scene(13, common.SYNRGBD)
    # one-hot oracle scores from the GT mask
    scores = np.zeros((common.IMG_SIZE, common.IMG_SIZE, common.NUM_SEG_CLASSES), np.float32)
    ys, xs = np.mgrid[0 : common.IMG_SIZE, 0 : common.IMG_SIZE]
    scores[ys, xs, s.seg_mask] = 1.0
    painted = scene.paint_points(s.points, scores, s.cam_pos, s.cam_rot, s.fx)
    assert painted.shape == (len(s.points), common.NUM_SEG_CLASSES)
    np.testing.assert_allclose(painted.sum(1), 1.0, atol=1e-5)
    fg = scene.point_fg_mask(painted)
    obj_pts = s.point_obj >= 0
    # oracle painting should label most visible object points as foreground
    assert fg[obj_pts].mean() > 0.45


def test_vote_targets_point_to_centers():
    s = scene.generate_scene(14, common.SYNRGBD)
    mask, off = scene.vote_targets(s.points, s)
    assert mask.shape == (len(s.points),)
    assert 0.0 < mask.mean() < 0.9
    voted = s.points[mask > 0.5] + off[mask > 0.5]
    centers = np.stack([o.center for o in s.objects])
    d = np.linalg.norm(voted[:, None, :] - centers[None], axis=2).min(1)
    assert np.quantile(d, 0.9) < 0.1, "votes must land on some GT center"


def test_synscan_denser_and_larger():
    a = scene.generate_scene(15, common.SYNRGBD)
    b = scene.generate_scene(15, common.SYNSCAN)
    assert len(b.points) == 2 * len(a.points)
    # synscan rooms are larger -> larger coordinate spread
    assert np.ptp(b.points[:, 0]) > np.ptp(a.points[:, 0])
