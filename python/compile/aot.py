"""AOT build orchestrator: train -> calibrate -> quantize -> export HLO.

Runs ONCE at build time (``make artifacts``); the Rust request path never
imports Python. Produces, under ``artifacts/``:

- ``weights/{dataset}_{model}.npz``       trained parameters (training cache)
- ``{dataset}_{model}_{net}_{prec}.hlo.txt``  one HLO-text module per
  network-only subgraph (point manipulation excluded — that is Rust's job)
- ``manifest.json``   shapes, dtypes, workload descriptors (FLOPs/bytes for
  the device simulator), model/dataset constants, role groups
- ``head_stats.json`` per-channel weight/activation stats (Fig. 6/7)

Usage: ``cd python && python -m compile.aot --out-dir ../artifacts [--quick]``
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import common, model, quantize, scene, train
from .common import (
    FEAT_DIM,
    FEAT_DIM_PLAIN,
    IMG_SIZE,
    NUM_PROPOSALS,
    NUM_SEEDS,
    NUM_SEG_CLASSES,
    PROPOSAL_K,
    SA_CONFIGS,
    SEED_FEAT,
)
from .export_utils import export_fn
from .model import FP_IN

F32 = jnp.float32


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def mlp_flops(n: int, widths: List[int]) -> int:
    return int(n * sum(2 * widths[i] * widths[i + 1] for i in range(len(widths) - 1)))


def conv_flops() -> int:
    """Segmenter FLOPs (3x3 convs at full/half/quarter resolution)."""
    c = model.SEG_CHANNELS
    hw = IMG_SIZE * IMG_SIZE
    f = 0
    f += 2 * hw * 9 * 3 * c[0]
    f += 2 * (hw // 4) * 9 * c[0] * c[1]
    f += 2 * (hw // 16) * 9 * c[1] * c[2]
    f += 2 * (hw // 16) * 9 * c[2] * c[3]
    f += 2 * (hw // 4) * 9 * c[3] * c[1]
    f += 2 * hw * 9 * (c[1] + c[1]) * c[0]
    f += 2 * hw * (c[0] + c[0]) * NUM_SEG_CLASSES
    return int(f)


def probe(shape) -> np.ndarray:
    """Deterministic probe input for cross-language parity fixtures:
    x[i] = sin(0.1 + 0.001*i) over the flattened buffer (mirrored in
    rust/tests). See fixtures.json consumers (Table 3 bench)."""
    n = int(np.prod(shape)) if shape else 1
    idx = np.arange(n, dtype=np.float64)
    return np.sin(0.1 + 0.001 * idx).astype(np.float32).reshape(shape)


# artifacts that get parity fixtures (suffix match)
FIXTURE_SUFFIXES = (
    "seg_fp32",
    "pointsplit_sa1_half_fp32",
    "pointsplit_sa1_half_int8",
    "pointsplit_sa4_full_fp32",
    "pointsplit_fp_fc_fp32",
    "pointsplit_vote_fp32",
    "pointsplit_vote_int8_role",
    "pointsplit_vote_int8_layer",
    "pointsplit_prop_fp32",
    "pointsplit_prop_int8_role",
    "votenet_sa1_full_fp32",
    "painted_vote_fp32",
)


class Exporter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.artifacts: List[Dict] = []
        self.fixtures: Dict[str, Dict] = {}
        os.makedirs(out_dir, exist_ok=True)
        os.makedirs(os.path.join(out_dir, "weights"), exist_ok=True)

    def add(self, name: str, fn, specs, meta: Dict, flops: int):
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        t0 = time.time()
        export_fn(fn, specs, path)
        if name.endswith(FIXTURE_SUFFIXES):
            ins = [jnp.asarray(probe(s.shape)) for s in specs]
            out = np.asarray(jax.jit(fn)(*ins)[0])
            self.fixtures[name] = {
                "output_shape": list(out.shape),
                "mean": float(out.mean()),
                "std": float(out.std()),
                "l1": float(np.abs(out).mean()),
                "first": [float(v) for v in out.flatten()[:12]],
            }
        bytes_in = int(sum(np.prod(s.shape) for s in specs) * 4)
        # int8 executables move quantized tensors over the interconnect
        wire = 1 if "int8" in meta.get("precision", "") else 4
        # declared output element count: the device simulator accounts
        # head-output wire/memory traffic per artifact, not via a constant
        out_shapes = jax.eval_shape(fn, *specs)
        out_elems = int(sum(np.prod(o.shape) for o in jax.tree_util.tree_leaves(out_shapes)))
        entry = {
            "name": name,
            "file": f"{name}.hlo.txt",
            "inputs": [{"shape": [int(x) for x in s.shape], "dtype": "f32"} for s in specs],
            "flops": int(flops),
            "bytes_in": bytes_in,
            "wire_bytes_per_elem": wire,
            "out_elems": out_elems,
            **meta,
        }
        self.artifacts.append(entry)
        print(f"    exported {name} ({time.time() - t0:.1f}s)")


def export_detector(
    ex: Exporter,
    dataset: str,
    model_name: str,
    params,
    painted: bool,
    precisions: Dict[str, Optional[model.QConfig]],
    shapes: List[str],
):
    """Export every network-only subgraph of one trained detector.

    precisions: {"fp32": None, "int8_role": qc, ...} — heads get per-scheme
    artifacts; backbone nets are exported once per unique backbone precision
    (fp32 + int8) since granularity only affects the head layers.
    """
    widths = model.sa_widths(painted)
    backbone_done = set()
    for prec, qc in precisions.items():
        bb_prec = "fp32" if prec == "fp32" else "int8"
        if bb_prec not in backbone_done:
            backbone_done.add(bb_prec)
            for li, (m, _, k, _) in enumerate(SA_CONFIGS):
                layer = li + 1
                for shape in shapes:
                    if shape == "half" and layer == 4:
                        continue  # pipelines fuse before SA4
                    b = m if shape == "full" else m // 2
                    cin = widths[li][0]

                    def fn(groups, layer=layer, qc=qc):
                        return (model.sa_pointnet_apply(params, layer, groups, qc=qc),)

                    ex.add(
                        f"{dataset}_{model_name}_sa{layer}_{shape}_{bb_prec}",
                        fn,
                        [spec(b, SA_CONFIGS[li][2], cin)],
                        {
                            "dataset": dataset,
                            "model": model_name,
                            "net": f"sa{layer}_{shape}",
                            "precision": bb_prec,
                        },
                        mlp_flops(b * SA_CONFIGS[li][2], widths[li]),
                    )
            ex.add(
                f"{dataset}_{model_name}_fp_fc_{bb_prec}",
                lambda f2, qc=qc: (model.fp_fc_apply(params, f2, qc=qc),),
                [spec(NUM_SEEDS, FP_IN)],
                {"dataset": dataset, "model": model_name, "net": "fp_fc", "precision": bb_prec},
                mlp_flops(NUM_SEEDS, [FP_IN, SEED_FEAT]),
            )
        # heads per precision/scheme
        ex.add(
            f"{dataset}_{model_name}_vote_{prec}",
            lambda sf, qc=qc: (model.vote_apply(params, sf, qc=qc),),
            [spec(NUM_SEEDS, SEED_FEAT)],
            {"dataset": dataset, "model": model_name, "net": "vote", "precision": prec},
            mlp_flops(NUM_SEEDS, [SEED_FEAT, 128, 128, common.VOTE_CH]),
        )
        ex.add(
            f"{dataset}_{model_name}_prop_{prec}",
            lambda g, qc=qc: (model.proposal_apply(params, g, qc=qc),),
            [spec(NUM_PROPOSALS, PROPOSAL_K, 3 + SEED_FEAT)],
            {"dataset": dataset, "model": model_name, "net": "prop", "precision": prec},
            mlp_flops(NUM_PROPOSALS * PROPOSAL_K, [3 + SEED_FEAT, 128, 64])
            + mlp_flops(NUM_PROPOSALS, [64, 64, common.PROPOSAL_CH]),
        )


def calib_inputs(pool: train.ScenePool, painted: bool, n: int = 16):
    """First n pool scenes as (xyz, feats, fg) calibration inputs."""
    out = []
    for i in range(min(n, len(pool.scenes))):
        s = pool.scenes[i]
        npts = pool.cfg.num_points
        sel = np.arange(len(s.points))[:npts]
        p = s.points[sel]
        h = p[:, 2:3]
        if painted:
            sc = pool.scores[i][sel]
            feats = np.concatenate([h, sc], 1).astype(np.float32)
            fg = (1.0 - sc[:, 0] > 0.5).astype(np.float32)
        else:
            feats = h.astype(np.float32)
            fg = np.zeros(len(p), np.float32)
        out.append((p.astype(np.float32), feats, fg))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true", help="tiny training for smoke runs")
    ap.add_argument("--datasets", default="synrgbd,synscan")
    args = ap.parse_args()

    if args.quick:
        train.SEG_STEPS = 12
        train.DET_STEPS = 12
        train.POOL_SIZE = 24

    ex = Exporter(args.out_dir)
    wdir = os.path.join(args.out_dir, "weights")
    head_stats_all: Dict = {}
    quant_meta: Dict = {}

    def cached(name, builder):
        path = os.path.join(wdir, f"{name}.npz")
        if os.path.exists(path):
            print(f"  [cache] {name}")
            return train.load_params(path)
        t0 = time.time()
        p = builder()
        train.save_params(path, p)
        print(f"  [trained] {name} ({time.time() - t0:.0f}s)")
        return p

    t_start = time.time()
    for ds_name in args.datasets.split(","):
        cfg = common.DATASETS[ds_name]
        print(f"== dataset {ds_name} ==")
        seg_params = cached(f"{ds_name}_seg", lambda: train.train_segmenter(cfg))
        pool = train.ScenePool(cfg, seg_params, size=train.POOL_SIZE)

        votenet = cached(
            f"{ds_name}_votenet", lambda: train.train_detector(pool, False, "full", seed=3)
        )
        painted = cached(
            f"{ds_name}_painted", lambda: train.train_detector(pool, True, "full", seed=4)
        )
        pointsplit = cached(
            f"{ds_name}_pointsplit", lambda: train.train_detector(pool, True, "split", seed=5)
        )

        # ---- calibration + QConfigs
        ci_plain = calib_inputs(pool, painted=False)
        ci_paint = calib_inputs(pool, painted=True)
        calib_vn = quantize.calibrate(votenet, ci_plain, variant="full")
        calib_pp = quantize.calibrate(painted, ci_paint, variant="full")
        calib_ps = quantize.calibrate(pointsplit, ci_paint, variant="split")

        head_stats_all[f"{ds_name}_pointsplit"] = quantize.head_stats(pointsplit, calib_ps)
        head_stats_all[f"{ds_name}_votenet"] = quantize.head_stats(votenet, calib_vn)

        # ---- segmenter artifacts
        for prec in ("fp32", "int8"):
            # (activation quantization of the segmenter is folded into its
            # scores; INT8 matters for the simulator's wire/compute model)
            ex.add(
                f"{ds_name}_seg_{prec}",
                lambda img: (model.segmenter_scores(seg_params, img),),
                [spec(IMG_SIZE, IMG_SIZE, 3)],
                {"dataset": ds_name, "model": "seg", "net": "seg", "precision": prec},
                conv_flops(),
            )

        # ---- detector artifacts
        export_detector(
            ex,
            ds_name,
            "votenet",
            votenet,
            painted=False,
            precisions={
                "fp32": None,
                "int8_layer": quantize.build_qconfig(votenet, calib_vn, "layer"),
            },
            shapes=["full"],
        )
        export_detector(
            ex,
            ds_name,
            "painted",
            painted,
            painted=True,
            precisions={
                "fp32": None,
                "int8_layer": quantize.build_qconfig(painted, calib_pp, "layer"),
            },
            shapes=["full", "half"],
        )
        export_detector(
            ex,
            ds_name,
            "pointsplit",
            pointsplit,
            painted=True,
            precisions={
                "fp32": None,
                **{
                    f"int8_{s}": quantize.build_qconfig(pointsplit, calib_ps, s)
                    for s in quantize.SCHEMES
                },
            },
            shapes=["full", "half"],
        )

    # ---- attention-head variants (Table 8) on the primary dataset
    cfg = common.SYNRGBD
    seg_params = train.load_params(os.path.join(wdir, "synrgbd_seg.npz"))
    pool = train.ScenePool(cfg, seg_params, size=min(train.POOL_SIZE, 192))
    attn_steps = max(train.DET_STEPS * 2 // 3, 8)
    for aname, apainted, avariant in (
        ("attn_plain", False, "full"),
        ("attn_painted", True, "full"),
        ("attn_split", True, "split"),
    ):
        pair = cached(
            f"synrgbd_{aname}",
            lambda: list(
                train.train_detector(
                    pool, apainted, avariant, steps=attn_steps, seed=11, head="attn"
                )
            ),
        )
        det_p, attn_p = pair[0], pair[1]
        widths = model.sa_widths(apainted)
        for li, (m, _, k, _) in enumerate(SA_CONFIGS):
            for shape in ["full"] + (["half"] if avariant != "full" and li < 3 else []):
                b = m if shape == "full" else m // 2
                ex.add(
                    f"synrgbd_{aname}_sa{li + 1}_{shape}_fp32",
                    lambda g, layer=li + 1: (model.sa_pointnet_apply(det_p, layer, g),),
                    [spec(b, SA_CONFIGS[li][2], widths[li][0])],
                    {
                        "dataset": "synrgbd",
                        "model": aname,
                        "net": f"sa{li + 1}_{shape}",
                        "precision": "fp32",
                    },
                    mlp_flops(b * k, widths[li]),
                )
        ex.add(
            f"synrgbd_{aname}_fp_fc_fp32",
            lambda f2: (model.fp_fc_apply(det_p, f2),),
            [spec(NUM_SEEDS, FP_IN)],
            {"dataset": "synrgbd", "model": aname, "net": "fp_fc", "precision": "fp32"},
            mlp_flops(NUM_SEEDS, [FP_IN, SEED_FEAT]),
        )
        ex.add(
            f"synrgbd_{aname}_attn_proj_fp32",
            lambda sf: (model.attn_proj(attn_p, sf),),
            [spec(NUM_SEEDS, SEED_FEAT)],
            {"dataset": "synrgbd", "model": aname, "net": "attn_proj", "precision": "fp32"},
            mlp_flops(NUM_SEEDS, [SEED_FEAT, model.ATTN_DIM]),
        )
        ex.add(
            f"synrgbd_{aname}_attn_decode_fp32",
            lambda cf, af: (model.attn_apply(attn_p, cf, af),),
            [spec(NUM_PROPOSALS, model.ATTN_DIM), spec(NUM_SEEDS, model.ATTN_DIM)],
            {"dataset": "synrgbd", "model": aname, "net": "attn_decode", "precision": "fp32"},
            # rough: per layer self+cross attention + FF over 32 candidates
            model.ATTN_LAYERS
            * (
                mlp_flops(NUM_PROPOSALS, [model.ATTN_DIM] * 5)
                + 2 * 2 * NUM_PROPOSALS * NUM_SEEDS * model.ATTN_DIM
                + mlp_flops(NUM_PROPOSALS, [model.ATTN_DIM, 2 * model.ATTN_DIM, model.ATTN_DIM])
            )
            + mlp_flops(NUM_PROPOSALS, [model.ATTN_DIM, common.PROPOSAL_CH]),
        )

    # ---- manifest
    quant_meta = {s: quantize.quant_param_count(s) for s in quantize.SCHEMES}
    (p_orig, m_orig), (p_ps, m_ps) = model.fp_layer_cost(paper_scale=False)
    (pp_orig, mm_orig), (pp_ps, mm_ps) = model.fp_layer_cost(paper_scale=True)
    manifest = {
        "classes": common.CLASSES,
        "mean_sizes": [list(s) for s in common.MEAN_SIZES],
        "num_heading_bin": common.NUM_HEADING_BIN,
        "num_seg_classes": NUM_SEG_CLASSES,
        "img_size": IMG_SIZE,
        "sa_configs": [
            {"m": m, "radius": r, "k": k, "mlp": list(mlp)} for m, r, k, mlp in SA_CONFIGS
        ],
        "num_seeds": NUM_SEEDS,
        "num_proposals": NUM_PROPOSALS,
        "proposal_radius": common.PROPOSAL_RADIUS,
        "proposal_k": PROPOSAL_K,
        "seed_feat": SEED_FEAT,
        "fp_in": FP_IN,
        "feat_dim_painted": FEAT_DIM,
        "feat_dim_plain": FEAT_DIM_PLAIN,
        "head_layout": {
            "center": list(common.SLICE_CENTER),
            "objectness": list(common.SLICE_OBJECTNESS),
            "heading_cls": list(common.SLICE_HEADING_CLS),
            "heading_reg": list(common.SLICE_HEADING_REG),
            "size_cls": list(common.SLICE_SIZE_CLS),
            "size_reg": list(common.SLICE_SIZE_REG),
            "sem_cls": list(common.SLICE_SEM_CLS),
        },
        "role_groups": {
            "vote": common.vote_role_groups(),
            "prop": common.proposal_role_groups(),
        },
        "quant_param_count": quant_meta,
        "fp_layer_cost": {
            "mini": {"orig": [p_orig, m_orig], "pointsplit": [p_ps, m_ps]},
            "paper_scale": {"orig": [pp_orig, mm_orig], "pointsplit": [pp_ps, mm_ps]},
        },
        "datasets": {
            name: {
                "num_points": c.num_points,
                "room_min": c.room_min,
                "room_max": c.room_max,
                "min_objects": c.min_objects,
                "max_objects": c.max_objects,
                "single_view": c.single_view,
                "depth_noise": c.depth_noise,
                "seg_noise": c.seg_noise,
            }
            for name, c in common.DATASETS.items()
        },
        "default_w0": common.DEFAULT_W0,
        "default_bias_layers": common.DEFAULT_BIAS_LAYERS,
        "artifacts": ex.artifacts,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    with open(os.path.join(args.out_dir, "head_stats.json"), "w") as f:
        json.dump(head_stats_all, f)
    with open(os.path.join(args.out_dir, "fixtures.json"), "w") as f:
        json.dump(ex.fixtures, f, indent=1)
    print(f"fixtures: {len(ex.fixtures)}")
    print(f"done: {len(ex.artifacts)} artifacts in {time.time() - t_start:.0f}s")


if __name__ == "__main__":
    main()
