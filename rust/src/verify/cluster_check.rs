//! Cluster-plan conservation (C rules): a fleet plan must be able to serve
//! every request the router can legally hand it, before any traffic flows.
//!
//! The router pins any config key to any alive box (rendezvous hashing over
//! the full key space) and the autoscaler clones the best
//! capacity-per-cost plan, so the static property to prove is
//! *conservation*: every box type's plan carries every config key, every
//! planned schedule stays on devices the box actually has, every planned
//! graph passes the full G/P/S/E rule set at the fleet batch size, and the
//! autoscale template exists and verifies under the same rules.
//!
//! - **C001** — a box type cannot serve the config set at all (its
//!   placement search has no feasible assignment, e.g. an EdgeTPU-only box
//!   with no point-op device), or its plan dropped/added config keys.
//! - **C002** — a planned schedule names a device outside the box's
//!   complement: the engine would simulate hardware the box does not have.
//! - **C003** — a planned config's graph/schedule fails the per-graph rule
//!   set (diagnostics are nested with a `box '<type>' key <k>:` locus).
//! - **C004** — no feasible autoscale template: every box type failed
//!   planning, so the first scale-up decision would abort the fleet.

use super::{verify_all, Report, Severity};
use crate::cluster::{plan_box, BoxPlan, ClusterSpec};
use crate::coordinator::DetectorConfig;
use crate::serving::{BatchPolicy, ServicePlanner};

/// Verify one provisioned box plan against the config-key space of size
/// `num_keys` (the router's pinnable keys) at the fleet batch size.
pub fn verify_box_plan(
    planner: &ServicePlanner,
    plan: &BoxPlan,
    num_keys: usize,
    num_points: usize,
    batch: usize,
) -> Report {
    let mut r = Report::new();
    let bt = &plan.box_type;
    if plan.configs.len() != num_keys {
        r.push(
            "C001",
            Severity::Error,
            format!("box '{}'", bt.name),
            format!(
                "plan carries {} configs but the router pins {num_keys} keys — \
                 requests for the missing keys would clamp to the wrong config",
                plan.configs.len()
            ),
            "plan_box must keep the cluster's config list (and key indexing) intact",
        );
    }
    for (k, cfg) in plan.configs.iter().enumerate() {
        let locus = format!("box '{}' key {k}", bt.name);
        for d in [cfg.schedule.point_dev(), cfg.schedule.nn_dev()] {
            if !bt.devices.contains(&d) {
                r.push(
                    "C002",
                    Severity::Error,
                    locus.clone(),
                    format!(
                        "planned schedule {:?} uses {} which this box does not have \
                         (complement: {})",
                        cfg.schedule,
                        d.name(),
                        bt.name
                    ),
                    "re-run the placement search over exactly the box's devices",
                );
            }
        }
        match planner.graph(cfg, num_points, false) {
            Err(e) => {
                r.push(
                    "C003",
                    Severity::Error,
                    locus,
                    format!("planned config's graph does not build: {e:#}"),
                    "the manifest must cover every config the cluster serves",
                );
            }
            Ok(g) => {
                let sub = verify_all(planner.sim(), planner.manifest(), &g, batch);
                r.merge_prefixed(&format!("box '{}' key {k}: ", bt.name), sub);
            }
        }
    }
    r
}

/// Verify a whole fleet spec: plan every distinct box type the way
/// `run_cluster` provisions it, check each plan for conservation, and
/// prove an autoscale template exists (C004) — the same
/// capacity-per-cost-unit maximum the autoscaler clones on scale-up.
pub fn verify_cluster(
    planner: &ServicePlanner,
    spec: &ClusterSpec,
    base_configs: &[DetectorConfig],
    num_points: usize,
    batch: &BatchPolicy,
    mix: &[f64],
) -> Report {
    let mut r = Report::new();
    let mut seen: Vec<String> = Vec::new();
    let mut plans: Vec<BoxPlan> = Vec::new();
    for bt in &spec.boxes {
        if seen.iter().any(|n| n == &bt.name) {
            continue; // one verification per box *type*
        }
        seen.push(bt.name.clone());
        match plan_box(planner, bt, base_configs, num_points, batch, mix) {
            Err(e) => {
                r.push(
                    "C001",
                    Severity::Error,
                    format!("box '{}'", bt.name),
                    format!("box type cannot serve the config set: {e:#}"),
                    "drop the box type from the spec or relax the config set",
                );
            }
            Ok(plan) => {
                r.merge(verify_box_plan(
                    planner,
                    &plan,
                    base_configs.len(),
                    num_points,
                    batch.max_batch,
                ));
                plans.push(plan);
            }
        }
    }
    // the autoscaler clones the best capacity-per-cost plan; with no
    // feasible plan the first scale-up decision has nothing to provision
    let template = plans.iter().max_by(|a, b| {
        (a.capacity_rps / a.box_type.cost_units)
            .total_cmp(&(b.capacity_rps / b.box_type.cost_units))
    });
    match template {
        Some(t) => {
            // the template plan was verified above; surface which type won
            // only if it somehow carries zero capacity (degenerate fleet)
            if t.capacity_rps.is_nan() || t.capacity_rps <= 0.0 {
                r.push(
                    "C004",
                    Severity::Error,
                    format!("autoscale template '{}'", t.box_type.name),
                    format!(
                        "template capacity is {} rps — scale-up cannot add capacity",
                        t.capacity_rps
                    ),
                    "fix the template box type's plan or the capacity model",
                );
            }
        }
        None => {
            if !spec.boxes.is_empty() {
                r.push(
                    "C004",
                    Severity::Error,
                    "autoscale template".to_string(),
                    "no box type yields a feasible plan: the autoscaler has no template to clone"
                        .to_string(),
                    "at least one box type must plan successfully",
                );
            }
        }
    }
    r
}
