//! Property-based tests over the coordinator substrates (custom harness in
//! util::prop — proptest is not vendored).

use pointsplit::coordinator::{DetectorConfig, Schedule, Variant};
use pointsplit::data::Box3;
use pointsplit::eval::{eval_map, iou3d, nms3d, Detection};
use pointsplit::pointops::{ball_query, biased_fps, fps};
use pointsplit::quant::{channel_minmax, partition, qdq_mse, ActQuant, Granularity};
use pointsplit::serving::dispatch::{run_traffic_trace, OutcomeKind, TrafficScenario};
use pointsplit::serving::{
    AdmissionQueue, AdmitResult, ArrivalPattern, BatchPolicy, LoadGen, Request, ServicePlanner,
    SloPolicy,
};
use pointsplit::sim::{DeviceKind, Precision, ScheduleSim, StageSpec, Workload, WorkloadKind};
use pointsplit::util::prop::{check, gen_box, gen_cloud, PropConfig};
use pointsplit::util::tensor::Tensor;

#[test]
fn prop_fps_distinct_indices_and_coverage() {
    check("fps-distinct", PropConfig::default(), |rng, size| {
        let n = (size * 4).max(8);
        let m = (n / 2).max(2);
        let cloud = gen_cloud(rng, n, 4.0);
        let idx = fps(&cloud, m);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        if s.len() != m {
            return Err(format!("duplicate indices: {} of {m}", s.len()));
        }
        if idx.iter().any(|&i| i >= n) {
            return Err("index out of range".into());
        }
        Ok(())
    });
}

#[test]
fn prop_biased_fps_monotone_in_w0() {
    check("biased-fps-monotone", PropConfig { cases: 32, seed: 11 }, |rng, size| {
        let n = (size * 8).max(64);
        let cloud = gen_cloud(rng, n, 4.0);
        let fg: Vec<f32> = cloud.iter().map(|p| if p[0] < 2.0 { 1.0 } else { 0.0 }).collect();
        let nfg = fg.iter().sum::<f32>();
        if nfg < 4.0 || nfg > n as f32 - 4.0 {
            return Ok(()); // degenerate foreground, skip
        }
        let m = (n / 4).max(4);
        let frac = |idx: &[usize]| idx.iter().map(|&i| fg[i]).sum::<f32>() / m as f32;
        let lo = frac(&biased_fps(&cloud, m, &fg, 1.0));
        let hi = frac(&biased_fps(&cloud, m, &fg, 8.0));
        if hi + 1e-6 < lo {
            return Err(format!("w0=8 sampled less fg ({hi}) than w0=1 ({lo})"));
        }
        Ok(())
    });
}

#[test]
fn prop_ball_query_members_valid() {
    check("ball-query-valid", PropConfig::default(), |rng, size| {
        let n = (size * 4).max(16);
        let cloud = gen_cloud(rng, n, 2.0);
        let m = (n / 4).max(1);
        let centers = fps(&cloud, m);
        let r = 0.2 + rng.f32() * 0.8;
        let k = 1 + rng.below(16);
        let groups = ball_query(&cloud, &centers, r, k);
        for (g, &c) in groups.iter().zip(centers.iter()) {
            if g.len() != k {
                return Err("wrong group size".into());
            }
            let first = g[0];
            for &j in g {
                let d2: f32 = (0..3).map(|d| (cloud[j][d] - cloud[c][d]).powi(2)).sum();
                if d2 > r * r + 1e-5 && j != first {
                    return Err(format!("member outside radius: {} > {}", d2.sqrt(), r));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_iou_bounds_and_symmetry() {
    check("iou-bounds", PropConfig { cases: 128, seed: 5 }, |rng, _| {
        let a = gen_box(rng, 4.0);
        let b = gen_box(rng, 4.0);
        let ab = iou3d(&a, &b);
        let ba = iou3d(&b, &a);
        if !(0.0..=1.0).contains(&ab) {
            return Err(format!("iou out of range: {ab}"));
        }
        if (ab - ba).abs() > 1e-6 {
            return Err(format!("asymmetric: {ab} vs {ba}"));
        }
        if (iou3d(&a, &a) - 1.0).abs() > 1e-6 {
            return Err("self-iou != 1".into());
        }
        Ok(())
    });
}

#[test]
fn prop_iou_shrinking_box_reduces_iou() {
    check("iou-monotone", PropConfig { cases: 64, seed: 9 }, |rng, _| {
        let a = gen_box(rng, 2.0);
        let mut small = a;
        small.size = [a.size[0] * 0.5, a.size[1] * 0.5, a.size[2] * 0.5];
        let iou = iou3d(&a, &small);
        // volume ratio 1/8 -> IoU exactly 0.125 (nested boxes)
        if (iou - 0.125).abs() > 1e-3 {
            return Err(format!("nested iou {iou} != 0.125"));
        }
        Ok(())
    });
}

#[test]
fn prop_nms_output_sorted_and_non_overlapping() {
    check("nms-invariants", PropConfig { cases: 48, seed: 21 }, |rng, size| {
        let boxes: Vec<Box3> = (0..size.max(2)).map(|_| gen_box(rng, 3.0)).collect();
        let keep = nms3d(&boxes, 0.25);
        for w in keep.windows(2) {
            if boxes[w[0]].score < boxes[w[1]].score {
                return Err("not sorted by score".into());
            }
        }
        for (i, &a) in keep.iter().enumerate() {
            for &b in keep.iter().skip(i + 1) {
                if iou3d(&boxes[a], &boxes[b]) > 0.25 + 1e-9 {
                    return Err("kept overlapping pair".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_map_perfect_detections_score_one() {
    check("map-perfect", PropConfig { cases: 32, seed: 31 }, |rng, size| {
        let n = size.max(1).min(20);
        let mut gts = vec![Vec::new()];
        let mut dets = Vec::new();
        for i in 0..n {
            let mut b = gen_box(rng, 3.0);
            b.center[0] += 10.0 * i as f32; // keep disjoint
            b.score = 1.0;
            gts[0].push(b);
            let mut d = b;
            d.score = rng.f32();
            dets.push(Detection { scene: 0, b: d });
        }
        let r = eval_map(&dets, &gts, 10, 0.25);
        if (r.map - 1.0).abs() > 1e-9 {
            return Err(format!("perfect detections mAP {} != 1", r.map));
        }
        Ok(())
    });
}

#[test]
fn prop_quant_finer_granularity_never_worse() {
    check("quant-monotone", PropConfig { cases: 24, seed: 41 }, |rng, size| {
        let n = (size * 4).max(32);
        let c = 24;
        let mut data = Vec::with_capacity(n * c);
        for _ in 0..n {
            for ch in 0..c {
                let sigma = 0.05 + 2.0 * (ch % 3) as f64;
                data.push(rng.normal_scaled(0.0, sigma) as f32);
            }
        }
        let t = Tensor::new(vec![n, c], data);
        let roles = vec![(0..8).collect::<Vec<_>>(), (8..16).collect(), (16..24).collect()];
        let (lo, hi) = channel_minmax(&t);
        let mk = |g| ActQuant::calibrate(&lo, &hi, &partition(g, c, &roles));
        let e_layer = qdq_mse(&t, &mk(Granularity::Layer)).map_err(|e| e.to_string())?;
        let e_chan = qdq_mse(&t, &mk(Granularity::Channel)).map_err(|e| e.to_string())?;
        if e_chan > e_layer + 1e-12 {
            return Err(format!("channel-wise worse than layer-wise: {e_chan} > {e_layer}"));
        }
        Ok(())
    });
}

#[test]
fn prop_schedule_respects_deps_and_devices() {
    check("schedule-valid", PropConfig { cases: 48, seed: 51 }, |rng, size| {
        // random DAG of point ops (GPU) and int8 NNs (EdgeTPU)
        let n = size.max(2).min(30);
        let mut stages = Vec::new();
        for i in 0..n {
            let nn = rng.f32() < 0.5;
            let deps: Vec<usize> =
                (0..i).filter(|_| rng.f32() < 0.25).collect();
            stages.push(StageSpec {
                name: format!("s{i}"),
                device: if nn { DeviceKind::EdgeTpu } else { DeviceKind::Gpu },
                precision: if nn { Precision::Int8 } else { Precision::Fp32 },
                workload: Workload {
                    kind: if nn { WorkloadKind::NeuralNet } else { WorkloadKind::PointOp },
                    flops: 1_000 + rng.below(5_000_000) as u64,
                    mem_bytes: rng.below(100_000) as u64,
                    wire_bytes: rng.below(50_000) as u64,
                },
                deps,
            });
        }
        let tl = ScheduleSim::new().run(&stages);
        // rebuild name -> interval
        let find = |i: usize| tl.stages.iter().find(|s| s.name == format!("s{i}")).unwrap();
        for (i, s) in stages.iter().enumerate() {
            let si = find(i);
            for &d in &s.deps {
                if si.end_ms < find(d).end_ms {
                    // starting is allowed (transfer), but completion order must
                    // respect the dep's completion
                    return Err(format!("s{i} ends before its dep s{d}"));
                }
                if si.compute_start_ms + 1e-9 < find(d).end_ms {
                    return Err(format!("s{i} computes before dep s{d} finished"));
                }
            }
        }
        // single occupancy per device
        for k in [DeviceKind::Gpu, DeviceKind::EdgeTpu] {
            let mut ivs: Vec<(f64, f64)> = tl
                .stages
                .iter()
                .filter(|s| s.device == k)
                .map(|s| (s.compute_start_ms, s.end_ms))
                .collect();
            ivs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for w in ivs.windows(2) {
                if w[1].0 + 1e-9 < w[0].1 {
                    return Err(format!("{:?} double-booked: {:?}", k, w));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pipelined_never_slower_than_chained() {
    check("overlap-helps", PropConfig { cases: 24, seed: 61 }, |rng, size| {
        // two independent chains must not be slower than one serialized chain
        let n = (size % 6).max(1);
        let mut mk = |i: usize, deps: Vec<usize>, nn: bool| StageSpec {
            name: format!("s{i}"),
            device: if nn { DeviceKind::EdgeTpu } else { DeviceKind::Gpu },
            precision: if nn { Precision::Int8 } else { Precision::Fp32 },
            workload: Workload {
                kind: if nn { WorkloadKind::NeuralNet } else { WorkloadKind::PointOp },
                flops: 500_000 + rng.below(2_000_000) as u64,
                mem_bytes: 0,
                wire_bytes: 1000,
            },
            deps,
        };
        // parallel: chains (0..n) and (n..2n) independent
        let mut par = Vec::new();
        for c in 0..2 {
            for i in 0..n {
                let gi = c * n + i;
                let deps = if i == 0 { vec![] } else { vec![gi - 1] };
                par.push(mk(gi, deps, i % 2 == 1));
            }
        }
        // serialized: same stages, each depends on the previous globally
        let mut ser = par.clone();
        for (i, s) in ser.iter_mut().enumerate() {
            if i > 0 {
                s.deps = vec![i - 1];
            }
        }
        let sim = ScheduleSim::new();
        let tp = sim.run(&par).total_ms;
        let ts = sim.run(&ser).total_ms;
        if tp > ts + 1e-6 {
            return Err(format!("parallel {tp} slower than serialized {ts}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// serving: admission queue + dispatcher invariants (ISSUE 1 satellite)
// ---------------------------------------------------------------------------

fn mk_req(id: u64, arrival: f64, deadline: f64, class: usize, key: usize) -> Request {
    Request { id, arrival_ms: arrival, deadline_ms: deadline, seed: id, class, key, client: 0 }
}

#[test]
fn prop_admission_queue_never_exceeds_capacity() {
    check("queue-capacity", PropConfig { cases: 48, seed: 71 }, |rng, size| {
        let cap = 1 + rng.below(size.max(2));
        let mut q = AdmissionQueue::new(cap, 2);
        let mut t = 0.0f64;
        let (mut offered, mut accepted, mut rejected) = (0u64, 0u64, 0u64);
        let (mut popped, mut expired) = (0u64, 0u64);
        for _ in 0..size * 3 {
            t += rng.f64() * 2.0;
            match rng.below(4) {
                0 | 1 => {
                    let r = mk_req(offered, t, t + rng.f64() * 6.0, rng.below(2), rng.below(2));
                    offered += 1;
                    match q.offer(r) {
                        AdmitResult::Admitted => accepted += 1,
                        AdmitResult::RejectedFull => rejected += 1,
                    }
                }
                2 => {
                    if q.pop().is_some() {
                        popped += 1;
                    }
                }
                _ => expired += q.expire(t).len() as u64,
            }
            if q.len() > cap {
                return Err(format!("depth {} exceeds capacity {cap}", q.len()));
            }
        }
        if accepted + rejected != offered {
            return Err("admission accounting leak".into());
        }
        if accepted != q.len() as u64 + popped + expired {
            return Err(format!(
                "conservation: accepted {accepted} != queued {} + popped {popped} + expired {expired}",
                q.len()
            ));
        }
        if q.stats.max_depth > cap {
            return Err("max_depth exceeds capacity".into());
        }
        Ok(())
    });
}

#[test]
fn prop_admission_queue_fifo_within_class() {
    check("queue-fifo-per-class", PropConfig { cases: 48, seed: 73 }, |rng, size| {
        let mut q = AdmissionQueue::new(size.max(4), 3);
        let mut next_id = 0u64;
        let mut popped: Vec<(usize, u64)> = Vec::new();
        for step in 0..size * 2 {
            if rng.f64() < 0.6 {
                let r = mk_req(next_id, step as f64, 1e9, rng.below(3), 0);
                next_id += 1;
                q.offer(r);
            } else if let Some(r) = q.pop() {
                popped.push((r.class, r.id));
            }
        }
        while let Some(r) = q.pop() {
            popped.push((r.class, r.id));
        }
        // within each priority class, pop order must equal arrival (id) order
        for class in 0..3 {
            let ids: Vec<u64> = popped.iter().filter(|(c, _)| *c == class).map(|&(_, i)| i).collect();
            for w in ids.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("class {class} popped out of order: {w:?}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dispatch_exactly_once() {
    // every admitted request is exactly once dispatched or shed; every
    // arrival resolves to exactly one terminal outcome
    let planner = ServicePlanner::synthetic();
    let sched = Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu };
    let cfg_a = DetectorConfig::new("synrgbd", Variant::PointSplit, true, sched);
    let cfg_b = DetectorConfig::new("synrgbd", Variant::VoteNet, true, sched);
    let base_cap = planner.capacity_rps(&cfg_a, 2048, 4).unwrap();
    check("dispatch-exactly-once", PropConfig { cases: 12, seed: 77 }, |rng, size| {
        let policy = [SloPolicy::None, SloPolicy::Shed, SloPolicy::Degrade][rng.below(3)];
        let mut load = LoadGen::simple(
            ArrivalPattern::Poisson { rate_rps: base_cap * (0.3 + rng.f64() * 1.9) },
            4_000.0 + (size as f64) * 100.0,
            200.0 + rng.f64() * 1200.0,
            rng.below(1 << 30) as u64,
        );
        load.hi_frac = rng.f64() * 0.5;
        load.mix = vec![2.0, 1.0];
        let sc = TrafficScenario {
            name: "prop".into(),
            configs: vec![cfg_a.clone(), cfg_b.clone()],
            num_points: 2048,
            load,
            queue_capacity: 4 + rng.below(40),
            batch: BatchPolicy { max_batch: 1 + rng.below(6), max_wait_ms: rng.f64() * 60.0 },
            policy,
        };
        let (rep, outcomes) = match run_traffic_trace(&sc, &planner, None) {
            Ok(v) => v,
            Err(e) => return Err(format!("traffic run failed: {e:#}")),
        };
        if outcomes.len() != rep.arrivals {
            return Err(format!("{} outcomes for {} arrivals", outcomes.len(), rep.arrivals));
        }
        let mut ids: Vec<u64> = outcomes.iter().map(|o| o.id).collect();
        ids.sort_unstable();
        for (expect, got) in ids.iter().enumerate() {
            if expect as u64 != *got {
                return Err(format!("outcome ids not exactly 0..n: saw {got} at {expect}"));
            }
        }
        let completed = outcomes.iter().filter(|o| o.kind == OutcomeKind::Completed).count();
        if completed != rep.completed {
            return Err("report.completed disagrees with outcomes".into());
        }
        if rep.completed + rep.rejected_full + rep.expired + rep.shed_slo != rep.arrivals {
            return Err(format!(
                "terminal accounting: {} + {} + {} + {} != {}",
                rep.completed, rep.rejected_full, rep.expired, rep.shed_slo, rep.arrivals
            ));
        }
        if policy == SloPolicy::None && rep.shed_slo != 0 {
            return Err("no-policy run must not shed".into());
        }
        Ok(())
    });
}
