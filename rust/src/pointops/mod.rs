//! Point-manipulation operations — the paper's "GPU" workload.
//!
//! The paper's key system observation: set abstraction interleaves point
//! manipulation (FPS, ball query — *not* executable on the NPU) with neural
//! nets (PointNet — NPU-friendly). Everything in this module is the former;
//! it runs on the Rust side of the split and is numerics-mirrored by
//! python/compile/sampling.py (parity checked by the Table 3 bench).

pub mod arena;
pub mod fps;
pub mod ballquery;
pub mod density;
pub mod interp;
pub mod paint;
pub mod soa;

pub use arena::{scratch_tracker, warm, with_arena, ScratchArena};
pub use ballquery::{ball_query, ball_query_par, ball_query_scalar, ball_query_soa};
pub use density::{density_biased_sample, local_density};
pub use fps::{
    biased_fps, biased_fps_from, biased_fps_from_par, biased_fps_par, biased_fps_soa, fps,
    fps_from, fps_from_par, fps_par, fps_scalar, fps_soa,
};
pub use interp::{
    three_nn_interpolate, three_nn_interpolate_par, three_nn_interpolate_scalar,
    three_nn_interpolate_soa,
};
pub use paint::{build_features, fg_mask, paint_points, paint_points_partial};
pub use soa::{padded_len, soa_bytes, PointsSoA, LANES};

use crate::util::tensor::Tensor;

/// Gather grouped features: relative xyz ++ point features.
///
/// xyz: (N,3), feats: optional (N,C), centers: indices (M,),
/// groups: (M,K) indices -> (M, K, 3+C).
pub fn group_features(
    xyz: &[[f32; 3]],
    feats: Option<&Tensor>,
    centers: &[usize],
    groups: &[Vec<usize>],
) -> Tensor {
    let m = centers.len();
    let k = groups.first().map_or(0, |g| g.len());
    let c = feats.map_or(0, |f| f.row_len());
    let mut data = Vec::with_capacity(m * k * (3 + c));
    for (ci, group) in centers.iter().zip(groups.iter()) {
        let center = xyz[*ci];
        for &pi in group {
            let p = xyz[pi];
            data.push(p[0] - center[0]);
            data.push(p[1] - center[1]);
            data.push(p[2] - center[2]);
            if let Some(f) = feats {
                data.extend_from_slice(f.row(pi));
            }
        }
    }
    Tensor::new(vec![m, k, 3 + c], data)
}

/// [`group_features`] over a cloud in SoA layout (the pipeline's steady
/// path). Same output bit-for-bit: per-point coordinates are identical and
/// the emit order is unchanged.
pub fn group_features_soa(
    pts: &PointsSoA,
    feats: Option<&Tensor>,
    centers: &[usize],
    groups: &[Vec<usize>],
) -> Tensor {
    let m = centers.len();
    let k = groups.first().map_or(0, |g| g.len());
    let c = feats.map_or(0, |f| f.row_len());
    let mut data = Vec::with_capacity(m * k * (3 + c));
    for (ci, group) in centers.iter().zip(groups.iter()) {
        let center = pts.get(*ci);
        for &pi in group {
            let p = pts.get(pi);
            data.push(p[0] - center[0]);
            data.push(p[1] - center[1]);
            data.push(p[2] - center[2]);
            if let Some(f) = feats {
                data.extend_from_slice(f.row(pi));
            }
        }
    }
    Tensor::new(vec![m, k, 3 + c], data)
}

/// Estimated FLOPs of one FPS call (the simulator's workload descriptor).
pub fn fps_flops(n: usize, m: usize) -> u64 {
    // each of m iterations: n distance evaluations (3 sub, 3 mul, 2 add) + min
    (m as u64) * (n as u64) * 9
}

/// Estimated FLOPs of one ball-query call.
pub fn ball_query_flops(n: usize, m: usize) -> u64 {
    (m as u64) * (n as u64) * 9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_features_layout() {
        let xyz = vec![[0.0, 0.0, 0.0], [1.0, 0.0, 0.0], [0.0, 2.0, 0.0]];
        let feats = Tensor::new(vec![3, 2], vec![10., 11., 20., 21., 30., 31.]);
        let g = group_features(&xyz, Some(&feats), &[1], &[vec![0, 2]]);
        assert_eq!(g.shape, vec![1, 2, 5]);
        // first neighbor: p0 - p1 = (-1,0,0) ++ feats[0]
        assert_eq!(&g.data[0..5], &[-1.0, 0.0, 0.0, 10.0, 11.0]);
        assert_eq!(&g.data[5..10], &[-1.0, 2.0, 0.0, 30.0, 31.0]);
    }

    #[test]
    fn group_features_soa_matches_interleaved() {
        let xyz = vec![[0.0, 0.0, 0.0], [1.0, 0.0, 0.0], [0.0, 2.0, 0.0], [3.0, 1.0, 2.0]];
        let feats = Tensor::new(vec![4, 2], vec![10., 11., 20., 21., 30., 31., 40., 41.]);
        let soa = PointsSoA::from_points(&xyz);
        let centers = vec![1, 3];
        let groups = vec![vec![0, 2], vec![3, 1]];
        assert_eq!(
            group_features_soa(&soa, Some(&feats), &centers, &groups),
            group_features(&xyz, Some(&feats), &centers, &groups)
        );
        assert_eq!(
            group_features_soa(&soa, None, &centers, &groups),
            group_features(&xyz, None, &centers, &groups)
        );
    }
}
