//! Minimal JSON parser/serializer (serde is not vendored in this image).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json`,
//! `artifacts/head_stats.json` and the config system: objects, arrays,
//! strings with escapes, numbers (f64), booleans, null.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// Like `get` but panics with a useful message — for required fields.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing required JSON key '{key}'"))
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Array of numbers -> Vec<f64> (panics on shape mismatch).
    pub fn f64_vec(&self) -> Vec<f64> {
        self.as_arr()
            .expect("expected JSON array")
            .iter()
            .map(|v| v.as_f64().expect("expected number"))
            .collect()
    }
    pub fn usize_vec(&self) -> Vec<usize> {
        self.f64_vec().into_iter().map(|x| x as usize).collect()
    }

    // ---- construction helpers --------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected character '{}'", c as char))),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected '{s}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // surrogate pairs
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.i + 2..self.i + 6])
                                            .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 6;
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                cp
                            };
                            out.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.req("c").as_bool(), Some(false));
        assert_eq!(v.req("a").as_arr().unwrap()[2].req("b").as_str(), Some("x"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s\"q",null,true],"m":{"n":-3}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }
}
