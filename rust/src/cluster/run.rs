//! The cluster event loop: one shared virtual clock driving a router, N
//! [`BoxEngine`]s, scripted faults, and the autoscaler.
//!
//! Event ordering at each timestamp is fixed (faults → spawns → arrivals →
//! dispatch → autoscale observation), which makes runs bit-deterministic
//! for a given scenario: the only randomness is the seeded load generator
//! and the seeded `Random` router baseline.
//!
//! Conservation invariant: every generated arrival resolves to exactly one
//! [`RequestOutcome`] — admission rejections and router no-target
//! rejections included, and a killed box's queue is drained and re-offered
//! through the router rather than dropped. `tests/cluster.rs` pins this
//! across fault schedules.

use anyhow::Result;

use crate::coordinator::DetectorConfig;
use crate::serving::dispatch::{BoxEngine, OutcomeKind, RequestOutcome};
use crate::serving::{BatchPolicy, LoadGen, Request, ServicePlanner, SloPolicy};
use crate::util::stats::Stats;

use super::autoscale::{self, AutoscalePolicy, ScaleDecision};
use super::inject::{self, Fault, FaultAction};
use super::metrics::{BoxReport, ClusterEvent, ClusterReport};
use super::router::{RouteTarget, Router, RouterPolicy};
use super::spec::{plan_box, BoxPlan, ClusterSpec};

/// One cluster serving experiment.
#[derive(Clone)]
pub struct ClusterScenario {
    pub name: String,
    pub spec: ClusterSpec,
    /// Base configs addressable by `Request::key`; each box re-schedules
    /// them for its own devices via the placement search.
    pub configs: Vec<DetectorConfig>,
    pub num_points: usize,
    /// Per-box admission queue bound.
    pub queue_capacity: usize,
    pub load: LoadGen,
    pub batch: BatchPolicy,
    pub policy: SloPolicy,
    pub router: RouterPolicy,
    pub router_seed: u64,
    pub faults: Vec<Fault>,
    pub autoscale: Option<AutoscalePolicy>,
}

/// Full result of a cluster run: the aggregate report, one terminal
/// outcome per arrival, and every routing decision (request id, box id,
/// config key) — re-routes after a drain appear as additional entries.
pub struct ClusterTrace {
    pub report: ClusterReport,
    pub outcomes: Vec<RequestOutcome>,
    pub routes: Vec<(u64, usize, usize)>,
}

/// A provisioned box instance inside the run.
struct LiveBox {
    id: usize,
    plan: BoxPlan,
    engine: BoxEngine,
    alive: bool,
    spawned_ms: f64,
    died_ms: Option<f64>,
    routed: usize,
}

/// Route one request over the currently-alive fleet; a fleet with no alive
/// boxes rejects (the request still resolves, as `RejectedFull`).
fn route_request(
    r: Request,
    boxes: &mut [LiveBox],
    router: &mut Router,
    routes: &mut Vec<(u64, usize, usize)>,
    outcomes: &mut Vec<RequestOutcome>,
) {
    let targets: Vec<RouteTarget> = boxes
        .iter()
        .filter(|b| b.alive)
        .map(|b| RouteTarget { id: b.id, queue_len: b.engine.queue_len() })
        .collect();
    // streaming sessions pin to the box holding their frame cache;
    // sessionless requests load-balance by config key as before
    let choice = if r.client != 0 {
        router.route_session(r.client, &targets)
    } else {
        router.route(r.key, &targets)
    };
    match choice {
        Some(id) => {
            let b = boxes
                .iter_mut()
                .find(|b| b.id == id)
                .expect("router only returns ids from the target list");
            b.routed += 1;
            routes.push((r.id, id, r.key));
            b.engine.offer(r, outcomes);
        }
        None => outcomes.push(RequestOutcome {
            id: r.id,
            kind: OutcomeKind::RejectedFull,
            on_time: false,
        }),
    }
}

/// Run a cluster scenario to completion on the simulated clock.
pub fn run_cluster(sc: &ClusterScenario, planner: &ServicePlanner) -> Result<ClusterTrace> {
    assert!(!sc.configs.is_empty(), "cluster scenario needs at least one detector config");

    // ---- provision the initial fleet (placement search per box type) ----
    let mut boxes: Vec<LiveBox> = Vec::new();
    for bt in &sc.spec.boxes {
        let plan = plan_box(planner, bt, &sc.configs, sc.num_points, &sc.batch, &sc.load.mix)?;
        let engine = BoxEngine::new(
            planner,
            &plan.configs,
            sc.num_points,
            sc.queue_capacity,
            sc.batch,
            sc.policy,
        )?;
        boxes.push(LiveBox {
            id: boxes.len(),
            plan,
            engine,
            alive: true,
            spawned_ms: 0.0,
            died_ms: None,
            routed: 0,
        });
    }
    let initial_capacity: f64 = boxes.iter().map(|b| b.plan.capacity_rps).sum();
    // what scale-up provisions: the initial type with the best capacity
    // per cost unit
    let scale_template: BoxPlan = boxes
        .iter()
        .map(|b| &b.plan)
        .max_by(|a, b| {
            (a.capacity_rps / a.box_type.cost_units)
                .total_cmp(&(b.capacity_rps / b.box_type.cost_units))
        })
        .expect("non-empty fleet")
        .clone();
    let mut next_box_id = boxes.len();

    let fault_sched = inject::schedule(&sc.faults);
    let mut fi = 0usize;

    let arrivals = sc.load.generate();
    let total = arrivals.len();
    let mut router = Router::new(sc.router, sc.router_seed);

    let mut outcomes: Vec<RequestOutcome> = Vec::with_capacity(total);
    let mut routes: Vec<(u64, usize, usize)> = Vec::with_capacity(total);
    let mut events: Vec<ClusterEvent> = Vec::new();
    let mut rerouted = 0usize;
    let mut pending_spawns: Vec<f64> = Vec::new();
    let mut next_check = sc.autoscale.map(|p| p.check_interval_ms).unwrap_or(f64::INFINITY);
    let mut cooldown_until = 0.0f64;

    let mut now = 0.0f64;
    let mut i = 0usize;
    loop {
        // 1) apply faults due at or before `now`
        while fi < fault_sched.len() && fault_sched[fi].0 <= now {
            let (_, action) = fault_sched[fi];
            fi += 1;
            match action {
                FaultAction::Kill(id) => {
                    let mut drained: Vec<Request> = Vec::new();
                    if let Some(b) = boxes.iter_mut().find(|b| b.id == id && b.alive) {
                        b.alive = false;
                        b.died_ms = Some(now);
                        drained = b.engine.drain();
                        events.push(ClusterEvent {
                            at_ms: now,
                            what: format!(
                                "box {id} ({}) killed; rerouting {} queued requests",
                                b.plan.box_type.name,
                                drained.len()
                            ),
                        });
                    }
                    rerouted += drained.len();
                    for r in drained {
                        route_request(r, &mut boxes, &mut router, &mut routes, &mut outcomes);
                    }
                }
                FaultAction::SetSlow(id, f) => {
                    if let Some(b) = boxes.iter_mut().find(|b| b.id == id && b.alive) {
                        b.engine.set_slow(f);
                        events.push(ClusterEvent {
                            at_ms: now,
                            what: format!("box {id} service-time factor set to {f}"),
                        });
                    }
                }
            }
        }

        // 2) boxes whose provisioning lag elapsed join the fleet
        let due = pending_spawns.iter().filter(|t| **t <= now).count();
        pending_spawns.retain(|t| *t > now);
        for _ in 0..due {
            let plan = scale_template.clone();
            let engine = BoxEngine::new(
                planner,
                &plan.configs,
                sc.num_points,
                sc.queue_capacity,
                sc.batch,
                sc.policy,
            )?;
            let id = next_box_id;
            next_box_id += 1;
            events.push(ClusterEvent {
                at_ms: now,
                what: format!("box {id} ({}) joined (scale-up)", plan.box_type.name),
            });
            boxes.push(LiveBox {
                id,
                plan,
                engine,
                alive: true,
                spawned_ms: now,
                died_ms: None,
                routed: 0,
            });
        }

        // 3) route arrivals due at or before `now`
        while i < total && arrivals[i].arrival_ms <= now {
            route_request(
                arrivals[i].clone(),
                &mut boxes,
                &mut router,
                &mut routes,
                &mut outcomes,
            );
            i += 1;
        }

        // 4) advance every alive engine (simulation-only: functional
        //    execution stays a single-box concern)
        let mut hints: Vec<f64> = Vec::new();
        for b in boxes.iter_mut().filter(|b| b.alive) {
            if let Some(h) = b.engine.advance(now, planner, None, &mut outcomes) {
                hints.push(h);
            }
        }

        // 5) autoscaler observation
        if let Some(pol) = &sc.autoscale {
            if now >= next_check {
                while next_check <= now {
                    next_check += pol.check_interval_ms;
                }
                let mut n_alive = 0usize;
                let mut fill_sum = 0.0f64;
                for b in boxes.iter().filter(|b| b.alive) {
                    n_alive += 1;
                    fill_sum +=
                        b.engine.queue_len() as f64 / b.engine.queue_capacity().max(1) as f64;
                }
                let fill = if n_alive > 0 { fill_sum / n_alive as f64 } else { 0.0 };
                let provisioned = n_alive + pending_spawns.len();
                if now >= cooldown_until && n_alive > 0 {
                    match autoscale::decide(pol, fill, provisioned) {
                        ScaleDecision::Up => {
                            pending_spawns.push(now + pol.spawn_delay_ms);
                            cooldown_until = now + pol.cooldown_ms;
                            events.push(ClusterEvent {
                                at_ms: now,
                                what: format!(
                                    "scale-up ordered (mean queue fill {:.0}%)",
                                    100.0 * fill
                                ),
                            });
                        }
                        ScaleDecision::Down => {
                            // retire the most recently added idle box —
                            // never one holding queued work
                            if let Some(b) = boxes
                                .iter_mut()
                                .filter(|b| b.alive && b.engine.is_idle(now))
                                .max_by(|a, b2| {
                                    a.spawned_ms
                                        .total_cmp(&b2.spawned_ms)
                                        .then(a.id.cmp(&b2.id))
                                })
                            {
                                b.alive = false;
                                b.died_ms = Some(now);
                                cooldown_until = now + pol.cooldown_ms;
                                events.push(ClusterEvent {
                                    at_ms: now,
                                    what: format!(
                                        "box {} ({}) retired (scale-down, idle)",
                                        b.id, b.plan.box_type.name
                                    ),
                                });
                            }
                        }
                        ScaleDecision::Hold => {}
                    }
                }
            }
        }

        // 6) advance the clock to the next event
        let mut t_next = f64::INFINITY;
        if let Some(r) = arrivals.get(i) {
            t_next = t_next.min(r.arrival_ms);
        }
        for h in &hints {
            t_next = t_next.min(*h);
        }
        if fi < fault_sched.len() {
            t_next = t_next.min(fault_sched[fi].0);
        }
        for t in &pending_spawns {
            t_next = t_next.min(*t);
        }
        if sc.autoscale.is_some() {
            // keep sampling only while there is anything left to drive
            let work_left = i < total
                || !pending_spawns.is_empty()
                || boxes.iter().any(|b| b.alive && !b.engine.is_idle(now));
            if work_left {
                t_next = t_next.min(next_check);
            }
        }
        if !t_next.is_finite() {
            break;
        }
        debug_assert!(t_next > now, "virtual clock must advance ({t_next} vs {now})");
        now = t_next;
    }

    // ---- aggregate ----
    let makespan_ms = boxes
        .iter()
        .map(|b| b.engine.stats().makespan_ms)
        .fold(0.0, f64::max);
    let end_ms = makespan_ms.max(sc.load.duration_ms).max(now);
    let makespan_s = (makespan_ms / 1000.0).max(sc.load.duration_ms / 1000.0).max(1e-9);

    let mut lat: Vec<f64> = Vec::new();
    let mut qwait: Vec<f64> = Vec::new();
    let mut completed = 0usize;
    let mut on_time = 0usize;
    let mut rejected_full = 0usize;
    let mut expired = 0usize;
    let mut shed_slo = 0usize;
    let mut degraded = 0usize;
    let mut batches = 0usize;
    let mut batched_reqs = 0usize;
    let mut stream_full = 0usize;
    let mut stream_partial = 0usize;
    let mut stream_reuse = 0usize;
    let mut session_evictions = 0usize;
    let mut stale_batches = 0usize;
    let mut cost_units = 0.0f64;
    let mut box_reports: Vec<BoxReport> = Vec::new();
    for b in &boxes {
        let st = b.engine.stats();
        completed += st.completed;
        on_time += st.on_time;
        rejected_full += st.rejected_full;
        expired += st.expired;
        shed_slo += st.shed_slo;
        degraded += st.degraded;
        batches += st.batches;
        batched_reqs += st.batched_reqs;
        stream_full += st.stream_full;
        stream_partial += st.stream_partial;
        stream_reuse += st.stream_reuse;
        session_evictions += st.stream_evictions;
        stale_batches += st.stale_batches;
        lat.extend_from_slice(b.engine.latencies());
        qwait.extend_from_slice(b.engine.queue_waits());
        let alive_s = (b.died_ms.unwrap_or(end_ms) - b.spawned_ms).max(0.0) / 1000.0;
        cost_units += b.plan.box_type.cost_units * alive_s;
        let denom = alive_s.max(1e-9);
        box_reports.push(BoxReport {
            id: b.id,
            type_name: b.plan.box_type.name.clone(),
            capacity_rps: b.plan.capacity_rps,
            alive: b.alive,
            alive_s,
            routed: b.routed,
            completed: st.completed,
            on_time: st.on_time,
            rejected_full: st.rejected_full,
            expired: st.expired,
            shed_slo: st.shed_slo,
            degraded: st.degraded,
            batches: st.batches,
            mean_batch: st.mean_batch(),
            util_gpu: st.busy_gpu_ms / 1000.0 / denom,
            util_npu: st.busy_npu_ms / 1000.0 / denom,
            util_cpu: st.busy_cpu_ms / 1000.0 / denom,
            stream_reuse_rate: st.stream_reuse_rate(),
            session_evictions: st.stream_evictions,
        });
    }
    // router-rejected requests (no alive box) count toward rejections too
    let router_rejected = outcomes
        .iter()
        .filter(|o| o.kind == OutcomeKind::RejectedFull)
        .count()
        .saturating_sub(rejected_full);
    rejected_full += router_rejected;

    let rates: Vec<f64> = box_reports
        .iter()
        .filter(|b| b.alive_s > 0.0)
        .map(|b| b.routed as f64 / b.alive_s)
        .collect();
    let routing_imbalance = if rates.is_empty() {
        1.0
    } else {
        let mean = rates.iter().sum::<f64>() / rates.len() as f64;
        if mean <= 0.0 { 1.0 } else { rates.iter().cloned().fold(0.0, f64::max) / mean }
    };

    let report = ClusterReport {
        scenario: sc.name.clone(),
        pattern: sc.load.pattern.name(),
        policy: sc.policy.name(),
        router: sc.router.name(),
        offered_rps: sc.load.pattern.mean_rps(),
        capacity_rps: initial_capacity,
        duration_s: sc.load.duration_ms / 1000.0,
        makespan_s,
        arrivals: total,
        completed,
        on_time,
        rejected_full,
        expired,
        shed_slo,
        degraded,
        rerouted,
        batches,
        mean_batch: if batches > 0 { batched_reqs as f64 / batches as f64 } else { 0.0 },
        latency_ms: Stats::from(lat),
        queue_wait_ms: Stats::from(qwait),
        slo_attainment: if total > 0 { on_time as f64 / total as f64 } else { 1.0 },
        goodput_rps: on_time as f64 / makespan_s,
        routing_imbalance,
        stream_full,
        stream_partial,
        stream_reuse,
        session_evictions,
        stale_batches,
        session_rebinds: router.session_rebinds(),
        cost_units,
        boxes: box_reports,
        events,
    };
    Ok(ClusterTrace { report, outcomes, routes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Schedule, Variant};
    use crate::serving::ArrivalPattern;
    use crate::sim::DeviceKind;

    fn base_cfg() -> DetectorConfig {
        DetectorConfig::new(
            "synrgbd",
            Variant::PointSplit,
            true,
            Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
        )
    }

    fn tiny_scenario(planner: &ServicePlanner) -> ClusterScenario {
        let cap = planner.capacity_rps(&base_cfg(), 2048, 4).unwrap();
        ClusterScenario {
            name: "tiny".to_string(),
            spec: ClusterSpec::parse("gpu+edgetpu,gpu,cpu+edgetpu").unwrap(),
            configs: vec![base_cfg()],
            num_points: 2048,
            queue_capacity: 16,
            load: LoadGen::simple(
                ArrivalPattern::Poisson { rate_rps: cap },
                10_000.0,
                2_000.0,
                11,
            ),
            batch: BatchPolicy { max_batch: 4, max_wait_ms: 25.0 },
            policy: SloPolicy::None,
            router: RouterPolicy::ConfigAffinity,
            router_seed: 11,
            faults: Vec::new(),
            autoscale: None,
        }
    }

    #[test]
    fn cluster_run_conserves_requests() {
        let planner = ServicePlanner::synthetic();
        let sc = tiny_scenario(&planner);
        let trace = run_cluster(&sc, &planner).unwrap();
        let r = &trace.report;
        assert!(r.arrivals > 0);
        assert_eq!(trace.outcomes.len(), r.arrivals, "one outcome per arrival");
        assert_eq!(r.completed + r.rejected_full + r.expired + r.shed_slo, r.arrivals);
        assert_eq!(r.boxes.len(), 3);
        // the three heterogeneous types planned differently
        assert!(r.capacity_rps > 0.0);
        assert!(r.boxes.iter().any(|b| b.completed > 0));
    }

    #[test]
    fn streaming_cluster_counts_frames_and_pins_sessions() {
        let planner = ServicePlanner::synthetic();
        let mut sc = tiny_scenario(&planner);
        sc.load.clients = 6;
        let trace = run_cluster(&sc, &planner).unwrap();
        let r = &trace.report;
        assert_eq!(trace.outcomes.len(), r.arrivals);
        assert!(r.stream_reuse > 0, "streaming traffic must hit the reuse tail");
        assert_eq!(r.session_rebinds, 0, "no faults, so no session should re-bind");
        // a session's frames must all land on the box holding its cache
        let client_of: std::collections::HashMap<u64, u64> =
            sc.load.generate().iter().map(|a| (a.id, a.client)).collect();
        let mut bound: std::collections::HashMap<u64, usize> = std::collections::HashMap::new();
        for (id, box_id, _) in &trace.routes {
            let c = client_of[id];
            if c == 0 {
                continue;
            }
            let e = bound.entry(c).or_insert(*box_id);
            assert_eq!(*e, *box_id, "client {c} bounced between boxes");
        }
        assert!(!bound.is_empty());
    }

    #[test]
    fn cluster_runs_are_deterministic() {
        let planner = ServicePlanner::synthetic();
        let sc = tiny_scenario(&planner);
        let a = run_cluster(&sc, &planner).unwrap();
        let b = run_cluster(&sc, &planner).unwrap();
        assert_eq!(a.report.arrivals, b.report.arrivals);
        assert_eq!(a.report.completed, b.report.completed);
        assert_eq!(a.report.on_time, b.report.on_time);
        assert_eq!(a.routes, b.routes);
        assert_eq!(a.report.latency_ms.p99, b.report.latency_ms.p99);
    }
}
